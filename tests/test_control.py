"""Grid-interactive control plane: online parity + closed-loop acceptance.

Two pillars:

* The online incremental detector (``sliding_bin_power`` carry API via
  ``ReplaySource`` + ``OnlineGoertzelDetector``) is *bit-identical* to
  one offline ``sliding_bin_power`` call on the concatenated trace,
  across uneven tick boundaries (ticks smaller than one window, a final
  partial tick).
* The closed loop on the canonical 9 Hz amplitude-ramp trace: the
  controller detects the trend before the (counterfactual) breach,
  dispatches a warm-started mitigation within the tick budget, and the
  post-intervention amplitude recedes below the release-hysteresis
  level.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import control
from repro.core.spec import example_specs
from repro.core.telemetry import escalation_init, escalation_step
from repro.kernels.goertzel.ops import (sliding_bin_power,
                                        sliding_carry_init,
                                        sliding_monitor_fused, trace_mean)

DT = 0.002
FREQS = (0.5, 1.0, 2.0, 9.0)


def _noisy_ramp(n=9000, seed=0):
    rng = np.random.default_rng(seed)
    t = np.arange(n) * DT
    return (5e8 + 4e7 * np.sin(2 * np.pi * 9.0 * t) * np.clip(t / 10, 0, 1)
            + 1e5 * rng.normal(size=n)).astype(np.float32)


# ---------------------------------------------------------------------------
# online == offline parity
# ---------------------------------------------------------------------------

class TestOnlineOfflineParity:
    def test_carry_api_uneven_chunks_bit_identical(self):
        x = _noisy_ramp()
        win = 2000
        off = np.asarray(sliding_bin_power(x, DT, FREQS, win=win,
                                           interpret=True))
        carry = sliding_carry_init(DT, FREQS, win=win,
                                   mean=float(trace_mean(x)))
        # ticks smaller than one window, window-crossing, and a final
        # partial tick (sums to 9000 = len(x))
        sizes = [7, 250, 1999, 2000, 3, 1211, 777, 2000, 753]
        assert sum(sizes) == len(x) and sizes[-1] < win
        outs = []
        pos = 0
        for s in sizes:
            amps, carry = sliding_bin_power(x[pos:pos + s], DT, FREQS,
                                            win=win, carry=carry)
            assert amps.shape == (s, len(FREQS))
            outs.append(amps)
            pos += s
        on = np.concatenate(outs, axis=0)
        assert on.shape == off.shape
        assert (on == off).all()

    def test_replay_source_detector_parity(self):
        """The satellite's exact shape: a trace through ReplaySource in
        uneven ticks, detector amplitudes bit-identical to offline."""
        x = _noisy_ramp(seed=3)
        win = 2000
        sizes = [900, 37, 2048, 1500, 1, 2000]   # remainder: default tick
        src = control.ReplaySource(x, DT, tick_s=0.5, tick_sizes=sizes)
        det = control.OnlineGoertzelDetector(DT, FREQS, window_s=win * DT,
                                             mean=float(trace_mean(x)),
                                             fused=False)
        assert det.win == win
        outs = []
        while (chunk := src.next_tick()) is not None:
            outs.append(det.step(chunk).tick_amps)
        on = np.concatenate(outs, axis=0)
        off = np.asarray(sliding_bin_power(x, DT, FREQS, win=win,
                                           interpret=True))
        assert on.shape == off.shape
        assert (on == off).all()

    def test_fused_detector_parity(self):
        """The default (fused) detector path: per-sample worst-bin
        amplitudes streamed through the fused monitor kernel are
        bit-identical to one offline ``sliding_monitor_fused`` call,
        the escalation level matches, and the O(K)-recombined per-bin
        ``frame.amps`` match the offline amplitudes at every tick end."""
        x = _noisy_ramp(seed=5)
        win = 2000
        thr, rel = 2.5e7, 2.0e7
        sizes = [900, 37, 2048, 1500, 1, 2000]
        src = control.ReplaySource(x, DT, tick_s=0.5, tick_sizes=sizes)
        det = control.OnlineGoertzelDetector(
            DT, FREQS, window_s=win * DT, mean=float(trace_mean(x)),
            threshold_w=thr, release_w=rel, sustain_s=0.5, cooldown_s=1.0)
        assert det.fused
        worsts, frames = [], []
        while (chunk := src.next_tick()) is not None:
            f = det.step(chunk)
            worsts.append(f.tick_worst)
            frames.append(f)
        on = np.concatenate(worsts)
        woff, loff, _, _ = sliding_monitor_fused(
            x, DT, FREQS, win=win, threshold=thr, release=rel,
            sustain_n=det.sustain_n, cool_n=det.cool_n, interpret=True)
        assert on.shape == (len(x),)
        assert (on == np.asarray(woff)).all()
        assert frames[-1].level == int(np.asarray(loff)[-1])
        assert max(f.level for f in frames) == int(np.asarray(loff).max())
        off_amps = np.asarray(sliding_bin_power(x, DT, FREQS, win=win,
                                                interpret=True))
        for f in frames:
            np.testing.assert_allclose(f.amps, off_amps[f.sample_idx],
                                       rtol=1e-6)

    def test_carry_resumes_mid_window(self):
        """Chunked ticks never re-prime: the first output after a tick
        boundary mid-window uses the carried residue, not a fresh one."""
        x = _noisy_ramp(n=5000, seed=1)
        win = 2000
        carry = sliding_carry_init(DT, FREQS, win=win,
                                   mean=float(trace_mean(x)))
        a1, carry = sliding_bin_power(x[:500], DT, FREQS, win=win,
                                      carry=carry)
        assert int(carry.offset) == 500 and int(carry.fill) == 500
        a2, carry = sliding_bin_power(x[500:], DT, FREQS, win=win,
                                      carry=carry)
        assert int(carry.offset) == 5000
        off = np.asarray(sliding_bin_power(x, DT, FREQS, win=win,
                                           interpret=True))
        assert (np.concatenate([a1, a2]) == off).all()


# ---------------------------------------------------------------------------
# shared escalation gating
# ---------------------------------------------------------------------------

class TestSharedEscalation:
    def _run(self, amps, **kw):
        carry = escalation_init()
        levels = []
        for i, a in enumerate(amps):
            carry, lvl = escalation_step(carry, jnp.float32(a),
                                         jnp.int32(i), **kw)
            levels.append(int(lvl))
        return levels

    def test_warmup_gate_blocks_early_triggers(self):
        kw = dict(threshold=1.0, win=4, n=100, sustain_n=1, cool_n=2)
        levels = self._run([5.0, 5.0, 5.0, 5.0, 5.0], **kw)
        # no escalation until i >= win-1 = 3
        assert levels[:3] == [0, 0, 0] and levels[3] >= 1

    def test_hysteresis_band_holds_level(self):
        """Between release and trigger the level must neither escalate
        nor release — the new hysteresis generalization."""
        kw = dict(threshold=1.0, win=1, n=100, sustain_n=1, cool_n=2,
                  release=0.5)
        amps = [2.0] + [0.7] * 10        # escalate, then sit in the band
        levels = self._run(amps, **kw)
        assert levels[0] == 1 and all(l == 1 for l in levels[1:])
        # below the release level it unwinds after cool_n
        levels = self._run([2.0, 0.4, 0.4, 0.4], **kw)
        assert levels[-1] == 0

    def test_default_release_matches_backstop_semantics(self):
        """release=None == the backstop's historical exact-threshold
        clear condition (the refactor must not drift)."""
        kw = dict(threshold=1.0, win=1, n=100, sustain_n=2, cool_n=2)
        amps = [2.0, 2.0, 0.9, 0.9, 2.0, 2.0, 2.0, 2.0]
        a = self._run(amps, **kw)
        b = self._run(amps, release=1.0, **kw)
        assert a == b

    def test_escalation_scan_matches_per_sample_step(self):
        """Property test: the blocked closed-form ``escalation_scan`` is
        bit-identical to folding ``escalation_class_step`` sample by
        sample — over run-structured class streams that exercise the
        homogeneous closed form, mixed-block fallback, CLS_PAD tail
        padding, and chunked carry hand-off at arbitrary boundaries."""
        from repro.core.telemetry import (escalation_class_step,
                                          escalation_scan)
        rng = np.random.default_rng(7)
        for trial in range(4):
            sustain = int(rng.integers(1, 9))
            cool = int(rng.integers(1, 9))
            n = int(rng.integers(50, 1500))
            cls = []
            while len(cls) < n:
                cls.extend([int(rng.integers(0, 3))]
                           * int(rng.integers(1, 400)))
            cls = np.asarray(cls[:n], np.int8)
            # per-sample reference
            c_ref = escalation_init()
            ref = []
            for i in range(n):
                c_ref, lvl = escalation_class_step(
                    c_ref, jnp.int8(cls[i]), jnp.int32(i),
                    sustain_n=sustain, cool_n=cool)
                ref.append(int(lvl))
            # one-shot blocked scan (block smaller than n: both paths run)
            c1, levels = escalation_scan(jnp.asarray(cls), jnp.int32(0),
                                         escalation_init(),
                                         sustain_n=sustain, cool_n=cool,
                                         block=128)
            assert np.asarray(levels).tolist() == ref
            assert [int(v) for v in c1] == [int(v) for v in c_ref]
            # chunked: same stream split at arbitrary boundaries
            cuts = sorted(rng.integers(0, n, size=3).tolist())
            c2 = escalation_init()
            got = []
            pos = 0
            for end in cuts + [n]:
                c2, lv = escalation_scan(jnp.asarray(cls[pos:end]),
                                         jnp.int32(pos), c2,
                                         sustain_n=sustain, cool_n=cool,
                                         block=128)
                got.extend(np.asarray(lv).tolist())
                pos = end
            assert got == ref
            assert [int(v) for v in c2] == [int(v) for v in c_ref]


# ---------------------------------------------------------------------------
# interventions
# ---------------------------------------------------------------------------

class TestInterventions:
    def test_stagger_nulls_target_bin(self):
        t = np.arange(20000) * DT
        w = (5e8 + 5e7 * np.sin(2 * np.pi * 9.0 * t)).astype(np.float32)
        iv = control.stagger_intervention(9.0, DT, n_groups=4)
        assert iv.params["comb_attenuation"] < 1e-10
        out = iv.transform(w, DT)
        amp = np.asarray(sliding_bin_power(out, DT, (9.0,), win=2000,
                                           interpret=True))[-1, 0]
        assert amp < 5e7 * 0.02          # > 50x attenuation at the bin

    def test_power_cap_bounds_amplitude(self):
        t = np.arange(20000) * DT
        w = (5e8 + 5e7 * np.sin(2 * np.pi * 9.0 * t)).astype(np.float32)
        release = 3e7
        iv = control.power_cap_intervention(w, DT, release_amp_w=release,
                                            n_chips=512)
        out = iv.transform(w, DT)
        assert out.max() <= iv.params["cap_w"] + 1
        assert out.min() >= iv.params["floor_w"] - 1
        assert iv.params["ballast_gflops"] > 0
        amp = np.asarray(sliding_bin_power(out, DT, (9.0,), win=2000,
                                           interpret=True))[-1, 0]
        assert amp < release             # square-wave residual < release

    def test_replay_source_closed_loop_physics(self):
        """Interventions act on the future only, compose over the
        pristine raw trace, and release restores it."""
        w = np.arange(100, dtype=np.float32) + 100.0
        src = control.ReplaySource(w, DT, tick_s=10 * DT)   # 10-sample ticks
        first = src.next_tick()
        assert (first == w[:10]).all()
        iv = control.Intervention(
            name="halve", params={},
            transform=lambda f, dt: (f * 0.5).astype(np.float32))
        src.apply_interventions([iv])
        second = src.next_tick()
        assert (second == w[10:20] * 0.5).all()       # future transformed
        assert (src.observed()[:10] == w[:10]).all()  # past untouched
        src.apply_interventions([])                   # release
        third = src.next_tick()
        assert (third == w[20:30]).all()              # raw restored


# ---------------------------------------------------------------------------
# the closed loop (acceptance)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def ramp_logs():
    """Cold + warm closed-loop runs on the canonical 9 Hz ramp (the warm
    run measures post-compilation dispatch latency)."""
    spec = example_specs(job_mw=500.0)["moderate"]
    w = control.synthesize_ramp(dt=DT)
    cold = control.watch_trace(w, DT, spec=spec, n_chips=512)
    warm = control.watch_trace(w, DT, spec=spec, n_chips=512)
    return cold, warm


class TestClosedLoop:
    def test_detects_before_breach(self, ramp_logs):
        cold, _ = ramp_logs
        s = cold.summary()
        assert s["first_escalate_t_s"] is not None
        # the controller acted before the uncontrolled trace would have
        # crossed the spec's breach amplitude
        assert s["counterfactual_breach_t_s"] is not None
        assert s["detection_lead_s"] > 0
        # and the controlled trace never actually breached
        assert s["breach_t_s"] is None or \
            s["breach_t_s"] >= s["first_escalate_t_s"]

    def test_dispatch_within_tick_budget(self, ramp_logs):
        cold, _ = ramp_logs
        esc = cold.first("escalate")
        disp = cold.first("dispatch:")
        assert disp is not None
        # dispatch_ticks=1: applied at the end of the deciding tick
        assert disp.tick == esc.tick

    def test_warm_dispatch_under_one_second(self, ramp_logs):
        _, warm = ramp_logs
        lats = warm.dispatch_latencies()
        assert lats, "warm run dispatched no interventions"
        assert max(lats) < 1.0

    def test_amplitude_recedes_below_release(self, ramp_logs):
        cold, _ = ramp_logs
        s = cold.summary()
        assert s["n_dispatches"] >= 1
        assert s["recession_t_s"] is not None
        # the recession row is genuinely below the release-hysteresis level
        row = next(r for r in cold.series
                   if r["t_s"] == s["recession_t_s"])
        assert max(row["amps_w"]) < cold.release_w < cold.trigger_w

    def test_log_is_json_safe(self, ramp_logs):
        cold, _ = ramp_logs
        import json
        blob = json.loads(cold.dumps())
        assert blob["summary"]["n_dispatches"] >= 1
        assert len(blob["series"]) == len(cold.series)
        assert "tick" in cold.timeline().splitlines()[0]


class TestServeWatch:
    def test_service_watch_replay(self):
        from repro.serve.power import PowerComplianceService
        service = PowerComplianceService(design_method="grid")
        w = control.synthesize_ramp(duration_s=24.0, ramp_start_s=4.0,
                                    ramp_end_s=16.0, dt=DT)
        out = service.watch(replay=w, dt=DT, n_chips=512, spec="moderate")
        assert out["spec"] == "moderate"
        assert out["summary"]["n_ticks"] > 0
        assert isinstance(out["timeline"], str)
        # JSON-safe end to end
        import json
        json.dumps(out)

"""Differentiable mitigation design: smooth relaxations, the spec hinge
loss, and the gradient/hybrid design solvers.

Three layers under test:

* each mitigation's ``smooth_tau`` relaxation — finite-difference gradient
  checks at tau > 0, and tau -> 0 forward parity with the hard semantics
  (tau = 0 runs the *same code path* as before this feature existed, so
  the engine/Study/serve layers are bit-unaffected);
* ``UtilitySpec.loss_jax`` — zero iff compliant, components aligned with
  the violation flags, differentiable w.r.t. the waveform;
* ``engine.design`` — gradient descent produces a spec-compliant config
  whose energy overhead is never worse than the best grid-search config,
  top-k alternatives, ``Study.optimize`` records, and the serve fallback.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as core
from repro.core import engine
from repro.core.hardware import DEFAULT_HW

DT = 0.002
TDP = DEFAULT_HW.chip.tdp_w


def chip_square(period=2.0, duty=0.75, secs=10.0, dt=DT):
    lo = DEFAULT_HW.chip.comm_w
    t = np.arange(int(secs / dt)) * dt
    return np.where((t % period) < duty * period, TDP, lo).astype(np.float32)


def central_diff(f, x, eps):
    return (f(x + eps) - f(x - eps)) / (2.0 * eps)


# ---------------------------------------------------------------------------
# finite-difference gradient checks (smooth_tau > 0)
# ---------------------------------------------------------------------------

def test_gpu_floor_smooth_gradient_matches_fd():
    w = jnp.asarray(chip_square())
    gf = core.GpuPowerSmoothing(mpf_frac=0.7, ramp_up_w_per_s=2000,
                                ramp_down_w_per_s=2000, stop_delay_s=1.0,
                                smooth_tau=0.05)

    def loss(mpf):
        out, _ = dataclasses.replace(gf, mpf_frac=mpf).apply_jax(w, DT)
        return jnp.mean(out) / TDP

    g = float(jax.grad(loss)(0.7))
    fd = float(central_diff(loss, 0.7, 0.01))
    assert g == pytest.approx(fd, rel=0.05)
    assert g > 0  # a higher floor burns more energy


def test_battery_smooth_gradient_matches_fd():
    w = jnp.asarray(chip_square() * 512)
    swing = float(w.max() - w.min())
    bat = core.RackBattery(capacity_j=0.2 * swing, max_discharge_w=swing,
                           max_charge_w=swing, target_tau_s=10.0,
                           smooth_tau=0.05)

    def loss(cap_frac):
        b = dataclasses.replace(bat, capacity_j=cap_frac * swing)
        out, _ = b.apply_jax(w, DT)
        return jnp.mean(jnp.square((out - out.mean()) / w.mean()))

    # capacity binding at 0.2x swing: more capacity -> smoother output
    g = float(jax.grad(loss)(0.2))
    fd = float(central_diff(loss, 0.2, 0.02))
    assert g == pytest.approx(fd, rel=0.1)
    assert g < 0


def test_firefly_smooth_gradient_matches_fd():
    # fine ballast quantization: the straight-through ceil's surrogate
    # gradient converges to the true sensitivity as steps shrink
    w = jnp.asarray(chip_square())
    ff = core.Firefly(smooth_tau=0.05, ballast_steps=256)

    def loss(engage):
        out, _ = dataclasses.replace(ff, engage_frac=engage).apply_jax(w, DT)
        return jnp.mean(out) / TDP

    g = float(jax.grad(loss)(0.85))
    fd = float(central_diff(loss, 0.85, 0.02))
    assert g == pytest.approx(fd, rel=0.1)
    assert g > 0  # filling deeper valleys costs energy


def test_backstop_off_path_gradient_is_zero_and_finite():
    # quiet trace: the monitor never escalates, the response is identity,
    # and every parameter gradient is (finite) zero — matching fd
    w = jnp.asarray(np.full(4000, 5e8, np.float32))
    bs = core.TelemetryBackstop(use_pallas=False, window_s=2.0,
                                smooth_tau=0.05)

    def loss(thresh):
        out, _ = dataclasses.replace(bs, amp_threshold_w=thresh).apply_jax(
            w, DT)
        return jnp.mean(out) / 5e8

    g = float(jax.grad(loss)(1e6))
    assert np.isfinite(g)
    assert abs(g) < 1e-9
    assert abs(float(central_diff(loss, 1e6, 1e4))) < 1e-9


def test_combined_smooth_gradient_matches_fd():
    n_chips = 64
    w = jnp.asarray(chip_square() * n_chips)
    swing = float(w.max() - w.min())
    gpu = core.GpuPowerSmoothing(mpf_frac=0.7, ramp_up_w_per_s=2000,
                                 ramp_down_w_per_s=2000, stop_delay_s=1.0,
                                 smooth_tau=0.05)
    bat = core.RackBattery(capacity_j=0.5 * swing, max_discharge_w=swing,
                           max_charge_w=swing, target_tau_s=10.0,
                           smooth_tau=0.05)

    def loss(mpf):
        cm = core.CombinedMitigation(
            dataclasses.replace(gpu, mpf_frac=mpf), bat, n_chips)
        out, _ = cm.apply_jax(w, DT)
        return jnp.mean(out) / (TDP * n_chips)

    g = float(jax.grad(loss)(0.7))
    fd = float(central_diff(loss, 0.7, 0.01))
    assert g == pytest.approx(fd, rel=0.05)


# ---------------------------------------------------------------------------
# tau -> 0 parity: smooth forward == hard forward
# ---------------------------------------------------------------------------

def test_tau_zero_is_the_hard_path_bitwise():
    w = chip_square()
    for hard in (core.GpuPowerSmoothing(mpf_frac=0.7, stop_delay_s=1.0),
                 core.RackBattery(capacity_j=1e5, max_discharge_w=1e5,
                                  max_charge_w=1e5),
                 core.Firefly(),
                 core.TelemetryBackstop(use_pallas=False, window_s=2.0)):
        out_h, _ = hard.apply(w, DT)
        out_0, _ = dataclasses.replace(hard, smooth_tau=0.0).apply(w, DT)
        np.testing.assert_array_equal(out_h, out_0)


def test_smooth_forward_converges_to_hard_as_tau_to_zero():
    w = chip_square()
    hard_gpu = core.GpuPowerSmoothing(mpf_frac=0.7, ramp_up_w_per_s=2000,
                                      ramp_down_w_per_s=2000,
                                      stop_delay_s=1.0)
    out_h, _ = hard_gpu.apply(w, DT)
    err = []
    for tau in (0.1, 0.01, 1e-4):
        out_s, _ = dataclasses.replace(hard_gpu, smooth_tau=tau).apply(w, DT)
        err.append(float(np.abs(out_s - out_h).max()) / TDP)
    assert err[0] > err[-1]
    assert err[-1] < 1e-3

    swing = float(w.max() - w.min()) * 512
    hard_bat = core.RackBattery(capacity_j=0.3 * swing, max_discharge_w=swing,
                                max_charge_w=swing, target_tau_s=10.0)
    out_h, _ = hard_bat.apply(w * 512, DT)
    out_s, _ = dataclasses.replace(hard_bat, smooth_tau=1e-4).apply(w * 512,
                                                                    DT)
    np.testing.assert_allclose(out_s, out_h, rtol=1e-4, atol=1e-3 * swing)

    hard_ff = core.Firefly()
    out_h, _ = hard_ff.apply(w, DT)
    out_s, _ = dataclasses.replace(hard_ff, smooth_tau=1e-4).apply(w, DT)
    np.testing.assert_allclose(out_s, out_h, atol=1e-2 * TDP)


def test_backstop_smooth_forward_is_exactly_hard():
    """The backstop relaxation is straight-through: escalation stays
    discrete in the forward pass at ANY tau (a fractional breaker action
    would be fiction), so smooth and hard forwards agree bitwise — on a
    trace that escalates, not just on the quiet path."""
    n = 8000
    t = np.arange(n) * DT
    # constant amplitude (gate saturated) AND a decaying oscillation that
    # keeps escalation alive while the bin amplitude hovers *near* the
    # threshold, where the engagement sigmoid is mid-range — the regime a
    # non-straight-through blend would leak into the forward pass
    traces = [5e8 + 2e6 * np.sin(2 * np.pi * 1.0 * t),
              5e8 + 2.5e6 * np.exp(-t / 4.0) * np.sin(2 * np.pi * 1.0 * t)]
    for w in (tr.astype(np.float32) for tr in traces):
        hard = core.TelemetryBackstop(use_pallas=False, window_s=2.0,
                                      sustain_s=0.5, amp_threshold_w=1e6)
        out_h, aux_h = hard.apply(w, DT)
        assert aux_h["max_level"] > 0  # the interesting (escalated) regime
        out_s, aux_s = dataclasses.replace(hard, smooth_tau=0.05).apply(w, DT)
        np.testing.assert_array_equal(out_h, out_s)
        np.testing.assert_array_equal(aux_h["levels"], aux_s["levels"])


# ---------------------------------------------------------------------------
# the spec hinge loss
# ---------------------------------------------------------------------------

def _spec(job_mw):
    return core.example_specs(job_mw=job_mw)["moderate"]


def test_loss_zero_iff_compliant():
    flat = np.full(4000, 1e8, np.float32)
    spec = _spec(100.0)
    total, comps = spec.loss_jax(flat, DT)
    assert float(total) == 0.0
    ok, _, _ = spec.validate_jax(flat, DT)
    assert bool(ok)

    square = chip_square() * 1e5  # ~100 MW of raw square wave
    total, comps = spec.loss_jax(square, DT)
    ok, flags, _ = spec.validate_jax(square, DT)
    assert not bool(ok)
    assert float(total) > 0
    # every hard violation has a positive hinge component behind it
    for name, flag in flags.items():
        if bool(flag):
            assert float(comps[name]) > 0, name


def test_loss_components_align_with_flags_at_zero_margin():
    spec = _spec(100.0)
    w = chip_square() * 1e5
    _, comps = spec.loss_jax(w, DT, margin=0.0)
    _, flags, _ = spec.validate_jax(w, DT)
    from repro.core.spec import VIOLATION_ORDER
    for name in VIOLATION_ORDER:
        if bool(flags[name]):
            assert float(comps[name]) > 0, name
        else:
            # a hinge can only fire when its metric exceeds the limit
            # (the sigmoid materiality gate makes band_energy approximate,
            # so allow a whisker)
            assert float(comps[name]) < 1e-2, name


def test_loss_differentiable_wrt_waveform():
    spec = _spec(100.0)
    w = jnp.asarray(chip_square() * 1e5)
    g = jax.grad(lambda x: spec.loss_jax(x, DT)[0])(w)
    assert np.all(np.isfinite(np.asarray(g)))
    assert float(jnp.abs(g).max()) > 0


def test_loss_margin_shrinks_the_feasible_region():
    spec = _spec(100.0)
    # just-compliant waveform: tiny ripple
    t = np.arange(4000) * DT
    w = (1e8 + 1e5 * np.sin(2 * np.pi * 0.5 * t)).astype(np.float32)
    ok, _, _ = spec.validate_jax(w, DT)
    t0, _ = spec.loss_jax(w, DT, margin=0.0)
    t9, _ = spec.loss_jax(w, DT, margin=0.9)
    assert float(t9) >= float(t0)


# ---------------------------------------------------------------------------
# design: grid top-k, gradient, hybrid
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def design_problem():
    tl = core.synthetic_timeline(period_s=2.0, comm_frac=0.25)
    cfg = core.WaveformConfig(dt=0.005, steps=8, jitter_s=0.005)
    n_chips = 256
    w = core.aggregate(core.chip_waveform(tl, cfg), n_chips, cfg)
    spec = core.example_specs(job_mw=w.mean() / 1e6)["tight"]
    return tl, cfg, n_chips, w, spec


def test_design_grid_top_k_alternatives(design_problem):
    _, cfg, n_chips, w, spec = design_problem
    swing = float(w.max() - w.min())
    mpf_grid = [0.0, 0.5, 0.9]
    cap_grid = [0.0] + [swing * 2.0 * f for f in (0.25, 1.0, 2.0)]
    sol = engine.design_grid(spec, w, cfg.dt, n_chips, mpf_grid, cap_grid,
                             swing=swing, top_k=3)
    assert sol is not None
    alts = sol["alternatives"]
    assert 1 <= len(alts) <= 3
    overheads = [a["energy_overhead"] for a in alts]
    assert overheads == sorted(overheads)
    # the top alternative is at least as cheap as the grid-order winner
    assert overheads[0] <= sol["energy_overhead"] + 1e-9
    # alternatives really are feasible configs on the hard semantics
    m, c = alts[0]["mpf_frac"], alts[0]["battery_capacity_j"]
    gpu, bat = engine._design_pair(spec, m, c, n_chips, swing, DEFAULT_HW)
    out = w
    if gpu is not None:
        per, _ = gpu.apply(w / n_chips, cfg.dt)
        out = per * n_chips
    if bat is not None:
        out, _ = bat.apply(out, cfg.dt)
    assert spec.validate(out, cfg.dt).ok


def test_design_gradient_compliant_and_no_worse_than_grid(design_problem):
    """Acceptance: gradient design produces a spec-compliant config on the
    square-wave workload with energy overhead <= the best grid config."""
    _, cfg, n_chips, w, spec = design_problem
    grid = engine.design(spec, w, cfg.dt, n_chips, method="grid", top_k=16)
    assert grid is not None
    best_grid = min(a["energy_overhead"] for a in grid["alternatives"])

    sol = engine.design(spec, w, cfg.dt, n_chips, method="gradient",
                        steps=40)
    assert sol is not None
    assert sol["report"].ok
    assert sol["energy_overhead"] <= best_grid + 1e-6
    # the returned mitigation objects are hard (tau=0) configs
    for m in (sol["device_mitigation"], sol["rack_mitigation"]):
        assert m is None or m.smooth_tau == 0.0
    assert sol["loss_history"].shape[1] == 40


def test_design_hybrid_never_worse_than_grid(design_problem):
    _, cfg, n_chips, w, spec = design_problem
    grid = engine.design(spec, w, cfg.dt, n_chips, method="grid")
    hyb = engine.design(spec, w, cfg.dt, n_chips, method="hybrid", steps=20)
    assert hyb is not None and hyb["report"].ok
    assert hyb["method"] == "hybrid"
    assert hyb["energy_overhead"] <= grid["energy_overhead"] + 1e-6
    # at (rounded-)equal overhead the refinement must keep the smaller
    # battery, not hand the win back to the grid on float noise
    if round(hyb["energy_overhead"], 6) == round(grid["energy_overhead"], 6):
        assert hyb["battery_capacity_j"] <= grid["battery_capacity_j"] + 1e-6


def test_design_gradient_survives_cap_zero_seed(design_problem):
    """A battery-off seed (the grid's MPF-only alternatives have
    capacity_j=0, and box projection can clip to exactly 0 mid-descent)
    must not NaN-poison its descent lane."""
    _, cfg, n_chips, w, spec = design_problem
    sol = engine.design_gradient(spec, w, cfg.dt, n_chips,
                                 seeds=[(0.5, 0.0)], steps=10)
    assert sol is not None and sol["report"].ok
    assert np.isfinite(sol["loss_history"]).all()


def test_design_respects_custom_hw_mpf_cap(design_problem):
    """A fleet whose feature caps MPF below the default grid's top rung:
    the default candidates clamp to it, and the serve fallback passes the
    service's hw through to the solver."""
    _, cfg, n_chips, w, spec = design_problem
    hw = dataclasses.replace(
        DEFAULT_HW, chip=dataclasses.replace(DEFAULT_HW.chip, mpf_max=0.8))
    sol = engine.design(spec, w, cfg.dt, n_chips, method="grid", hw=hw)
    assert sol is not None and sol["mpf_frac"] <= 0.8 + 1e-9

    from repro.serve.power import PowerComplianceService
    svc = PowerComplianceService(wave_cfg=cfg, hw=hw, mpf_grid=(),
                                 cap_fracs=(0.001,), design_method="grid")
    ans = svc.query(core.synthetic_timeline(2.0, 0.25), n_chips, "tight")
    assert ans["designed"] is not None
    assert ans["designed"]["mpf_frac"] <= 0.8 + 1e-9


def test_design_gradient_honors_top_k(design_problem):
    _, cfg, n_chips, w, spec = design_problem
    sol = engine.design(spec, w, cfg.dt, n_chips, method="gradient",
                        steps=10, top_k=2)
    assert sol is not None
    assert len(sol["alternatives"]) <= 2


def test_design_method_validation(design_problem):
    _, cfg, n_chips, w, spec = design_problem
    with pytest.raises(ValueError, match="method"):
        engine.design(spec, w, cfg.dt, n_chips, method="annealing")


def test_design_mitigation_gradient_public_face(design_problem):
    _, cfg, n_chips, w, spec = design_problem
    sol = core.design_mitigation(spec, w, cfg.dt, n_chips,
                                 method="gradient", steps=20)
    assert sol is not None and sol["report"].ok
    # serial confirmation aux is populated like the grid path's
    assert "aux" in sol


# ---------------------------------------------------------------------------
# Study.optimize + serve fallback
# ---------------------------------------------------------------------------

def test_study_optimize_designed_records():
    cfg = core.WaveformConfig(dt=0.005, steps=8, jitter_s=0.005)
    study = core.Study({"dense": core.synthetic_timeline(2.0, 0.25)},
                       fleets=[256], configs={"none": None},
                       specs=core.example_specs(job_mw=0.3),
                       wave_cfg=cfg)
    run = study.run()
    assert all(r["designed"] is False for r in run)
    assert len(run.filter(designed=True)) == 0

    opt = study.optimize(method="grid")
    assert len(opt) == 3  # one per spec
    for r in opt:
        assert r["designed"] is True
        assert r["config"] == "designed[grid]"
        assert "mpf_frac" in r and "battery_capacity_j" in r
        if r["spec_ok"]:
            assert r["swing_mitigated_mw"] <= r["swing_mw"] + 1e-9
    assert len(opt.filter(designed=True)) == len(opt)
    # designed rows export alongside declared ones
    both = core.StudyResult(records=run.records + opt.records)
    assert "designed" in both.to_csv().splitlines()[0]


def test_serve_design_fallback():
    cfg = core.WaveformConfig(dt=0.005, steps=8, jitter_s=0.005)
    from repro.serve.power import PowerComplianceService
    # a catalog that cannot pass tight: one starved battery
    svc = PowerComplianceService(wave_cfg=cfg, mpf_grid=(),
                                 cap_fracs=(0.001,),
                                 design_method="grid")
    tl = core.synthetic_timeline(2.0, 0.25)
    ans = svc.query(tl, 256, "tight")
    assert ans["compliant"]
    assert ans["designed"] is not None
    assert ans["recommended"] == ans["designed"]["config"]
    assert ans["designed"]["designed"] is True
    assert ans["passing"][0]["config"].startswith("designed")

    # fallback off: the same query is honestly non-compliant
    svc2 = PowerComplianceService(wave_cfg=cfg, mpf_grid=(),
                                  cap_fracs=(0.001,), design_fallback=False)
    ans2 = svc2.query(tl, 256, "tight")
    assert not ans2["compliant"]
    assert ans2["designed"] is None

"""Multi-host scenario-mesh driver: 2-process bit-parity against
single-process, primary-only global progress, launch helpers, and the
cross-process merge collectives.

The heavy test launches real ``jax.distributed`` worker subprocesses
(CPU + gloo, the subprocess-isolation pattern of test_streaming.py) and
asserts the merged ``StudyResult`` records equal the single-process
run's bit-for-bit — the acceptance-critical parity of PR 10.
"""
import json
import os
import sys

import numpy as np
import pytest

from repro.parallel import distributed
from repro.parallel.collectives import gather_rows, host_allgather
from repro.parallel.sharding import scenario_plan


# ---------------------------------------------------------------------------
# host-side helpers (no distributed runtime needed)
# ---------------------------------------------------------------------------

def test_initialize_is_noop_without_contract(monkeypatch):
    for var in (distributed.ENV_COORD, distributed.ENV_NPROCS,
                distributed.ENV_PID):
        monkeypatch.delenv(var, raising=False)
    assert distributed.initialize() is False
    assert distributed.is_primary()          # single-process is primary


def test_worker_env_contract():
    env = distributed.worker_env({"PYTHONPATH": "/elsewhere"},
                                 coordinator="localhost:12345",
                                 num_processes=2, process_id=1)
    assert env[distributed.ENV_COORD] == "localhost:12345"
    assert env[distributed.ENV_NPROCS] == "2"
    assert env[distributed.ENV_PID] == "1"
    src = env["PYTHONPATH"].split(os.pathsep)[0]
    assert os.path.isdir(os.path.join(src, "repro"))
    assert "/elsewhere" in env["PYTHONPATH"]


def test_free_port_is_bindable():
    import socket
    port = distributed.free_port()
    with socket.socket() as s:
        s.bind(("localhost", port))


def test_launch_workers_surfaces_worker_failure():
    with pytest.raises(RuntimeError, match=r"(?s)worker .* exited .*boom"):
        distributed.launch_workers(
            [sys.executable, "-c", "import sys; sys.exit('boom')"],
            num_processes=2, timeout=60)


# ---------------------------------------------------------------------------
# collectives: single-process branches are the engine's host pulls
# ---------------------------------------------------------------------------

def test_host_allgather_single_process_is_plain_asarray():
    tree = {"a": np.arange(6.0), "b": {"c": np.ones((4, 2))}, "n": None}
    out = host_allgather(tree, None)
    assert np.array_equal(out["a"], tree["a"])
    out2 = host_allgather(tree, scenario_plan(), take=3)
    assert np.array_equal(out2["a"], tree["a"][:3])
    assert np.array_equal(out2["b"]["c"], tree["b"]["c"][:3])
    assert out2["n"] is None


def test_gather_rows_single_process_matches_numpy():
    x = np.arange(24.0).reshape(6, 4)
    got = gather_rows(x, [4, 0, 2], None, length=3)
    assert np.array_equal(got, x[[4, 0, 2]][:, :3])
    got2 = gather_rows(x, [1, 1], scenario_plan())
    assert np.array_equal(got2, x[[1, 1]])


# ---------------------------------------------------------------------------
# 2-process parity + primary-only progress (subprocess-simulated)
# ---------------------------------------------------------------------------

WORKER = """
import json, sys
from repro.parallel import distributed as D

assert D.initialize(), "REPRO_DIST_* contract missing"
study = D._smoke_study()
study.plan = D.distributed_plan()
calls = []
res = study.run(stream=5, on_chunk=lambda d, t, e: calls.append((d, t)))
if D.is_primary():
    assert calls, "primary saw no on_chunk emissions"
    done, total = calls[-1]
    assert done == total == study.n_rows, (calls, study.n_rows)
    assert all(t == study.n_rows for _, t in calls), calls
    res.to_json(sys.argv[1])
else:
    assert calls == [], f"non-primary emitted progress: {calls}"
print("DIST_WORKER_OK", D.process_index(), len(res), flush=True)
"""


def test_two_process_run_bit_identical_and_progress_global(tmp_path):
    ref = distributed._smoke_study().run(stream=5)
    script = tmp_path / "worker.py"
    script.write_text(WORKER)
    out = tmp_path / "records.json"
    done = distributed.launch_workers(
        [sys.executable, str(script), str(out)], num_processes=2,
        timeout=600)
    for r in done:
        assert "DIST_WORKER_OK" in r.stdout, r.stdout
    got = json.loads(out.read_text())
    assert got == ref.to_records(), (
        "2-process StudyResult differs from single-process")

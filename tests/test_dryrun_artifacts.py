"""Validate the committed multi-pod dry-run artifacts: every (arch x shape
x mesh) cell compiled, with coherent cost/memory/collective numbers.

Skipped when artifacts/dryrun is absent (e.g. fresh checkout) — regenerate
with: PYTHONPATH=src python -m repro.launch.dryrun --mesh both
"""
import glob
import json
import os

import pytest

from repro.configs import ARCH_IDS, get_config, shapes_for

_ROOT = os.path.join(os.path.dirname(__file__), "..", "artifacts")
ART = os.path.join(_ROOT, "dryrun")
ART_V2 = os.path.join(_ROOT, "dryrun_v2")

pytestmark = pytest.mark.skipif(
    not os.path.isdir(ART), reason="dry-run artifacts not generated")


def _cells():
    out = []
    for arch in ARCH_IDS:
        for shape in shapes_for(get_config(arch)):
            for mesh in ("single", "multi"):
                out.append((arch, shape.name, mesh))
    return out


@pytest.mark.parametrize("root", [ART, ART_V2])
def test_all_cells_present_and_ok(root):
    if not os.path.isdir(root):
        pytest.skip("sweep missing")
    cells = _cells()
    assert len(cells) == 64
    missing, failed = [], []
    for arch, shape, mesh in cells:
        p = os.path.join(root, f"{arch}__{shape}__{mesh}.json")
        if not os.path.exists(p):
            missing.append((arch, shape, mesh))
            continue
        with open(p) as f:
            d = json.load(f)
        if "error" in d:
            failed.append((arch, shape, mesh))
    assert not missing, f"missing cells: {missing}"
    assert not failed, f"failed cells: {failed}"


@pytest.mark.parametrize("mesh,chips", [("single", 256), ("multi", 512)])
def test_cell_contents_coherent(mesh, chips):
    for p in glob.glob(os.path.join(ART, f"*__{mesh}.json")):
        with open(p) as f:
            d = json.load(f)
        if "error" in d:
            continue
        assert d["n_chips"] == chips, p
        assert d["exact"]["flops"] > 0, p
        assert d["exact"]["bytes"] > 0, p
        assert d["memory"]["state_bytes_per_device"] > 0, p
        # multi-pod mesh must actually use the pod axis: gradient sync
        # crosses pods for train cells -> nonzero collectives
        if d["kind"] == "train":
            assert sum(d["collectives"].values()) > 0, p


def test_train_flops_close_to_6nd():
    """MODEL_FLOPS = 6*N*D should be within ~3.5x of compiled HLO flops
    (remat + causal-chunk overcompute account for the gap, see §Roofline)."""
    for arch in ARCH_IDS:
        p = os.path.join(ART, f"{arch}__train_4k__single.json")
        with open(p) as f:
            d = json.load(f)
        if "error" in d:
            continue
        n = d["active_params"]
        model_flops = 6.0 * n * 4096 * 256
        ratio = d["exact"]["flops"] / model_flops
        assert 0.9 < ratio < 5.0, (arch, ratio)

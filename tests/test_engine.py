"""Batched scenario engine: parity with the serial path + batching laws.

The contract under test: for every mitigation, ``simulate_batch`` /
``apply_batch`` (vmapped apply_jax) produce the same waveforms, swing
stats, band reports and spec verdicts as looping the serial ``simulate`` /
``apply`` over the scenarios one at a time.
"""
import numpy as np
import pytest

import repro.core as core
from repro.core import engine
from repro.core.hardware import DEFAULT_HW

DT = 0.002
N_CHIPS = 512


def _timeline(period=1.0, comm=0.3, moe=False):
    return core.synthetic_timeline(period_s=period, comm_frac=comm,
                                   moe_notch=moe)


def _cfg(**kw):
    kw.setdefault("dt", DT)
    kw.setdefault("steps", 6)
    return core.WaveformConfig(**kw)


def _chip_wave():
    return core.chip_waveform(_timeline(), _cfg())


def _dc_wave():
    cfg = _cfg(jitter_s=0.002)
    return core.aggregate(core.chip_waveform(_timeline(), cfg), N_CHIPS, cfg)


def _gpu(mpf, **kw):
    kw.setdefault("ramp_up_w_per_s", 2000)
    kw.setdefault("ramp_down_w_per_s", 2000)
    kw.setdefault("stop_delay_s", 1.0)
    return core.GpuPowerSmoothing(mpf_frac=mpf, **kw)


def _bat(cap, swing):
    return core.RackBattery(capacity_j=cap, max_discharge_w=swing,
                            max_charge_w=swing, target_tau_s=5.0)


# ---------------------------------------------------------------------------
# apply_batch: vmapped apply_jax == per-config serial apply
# ---------------------------------------------------------------------------

def _grids():
    chip = _chip_wave()
    dc = _dc_wave()
    swing_c = float(chip.max() - chip.min())
    swing_d = float(dc.max() - dc.min())
    return {
        "gpu_floor": (chip, [_gpu(m) for m in (0.5, 0.65, 0.9)]),
        "battery": (dc, [_bat(f * swing_d, swing_d) for f in (0.5, 1.0, 2.0)]),
        "firefly": (chip, [core.Firefly(engage_frac=e, threshold_frac=e - 0.05)
                           for e in (0.85, 0.95)]),
        "backstop": (dc, [core.TelemetryBackstop(
            critical_hz=(0.5, 1.0), window_s=2.0, sustain_s=0.5,
            amp_threshold_w=a * swing_d) for a in (0.05, 10.0)]),
        "backstop_jnp": (dc, [core.TelemetryBackstop(
            critical_hz=(0.5, 1.0), window_s=2.0, sustain_s=0.5,
            amp_threshold_w=a * swing_d, use_pallas=False)
            for a in (0.05, 10.0)]),
        "combined": (dc, [core.CombinedMitigation(
            _gpu(m), _bat(swing_d, swing_d), N_CHIPS) for m in (0.5, 0.9)]),
        "stack": (chip, [core.Stack([_gpu(m), _bat(2 * swing_c, swing_c)])
                         for m in (0.5, 0.9)]),
    }


@pytest.mark.parametrize("name", ["gpu_floor", "battery", "firefly",
                                  "backstop", "backstop_jnp", "combined",
                                  "stack"])
def test_apply_batch_matches_serial(name):
    w, mits = _grids()[name]
    outs, aux = core.apply_batch(mits, w, DT)
    assert outs.shape == (len(mits), len(w))
    for i, m in enumerate(mits):
        ref, ref_aux = m.apply(w, DT)
        np.testing.assert_allclose(outs[i], ref, rtol=1e-5, atol=1e-3)
        # scalar aux entries agree row-by-row
        for k, v in ref_aux.items():
            if isinstance(v, float):
                np.testing.assert_allclose(
                    np.asarray(aux[k][i], np.float64), v,
                    rtol=1e-4, atol=1e-6, err_msg=f"{name}.{k}")


# ---------------------------------------------------------------------------
# simulate_batch: one compiled call == loop of serial simulate
# ---------------------------------------------------------------------------

def _scenarios():
    """(device, rack) configs covering every mitigation class, batchable
    per group."""
    dc = _dc_wave()
    swing = float(dc.max() - dc.min())
    return {
        "device_gpu": ([_gpu(m) for m in (0.5, 0.8, 0.9)], None),
        "device_firefly": ([core.Firefly(engage_frac=e, threshold_frac=e - 0.05)
                            for e in (0.85, 0.95)], None),
        "rack_battery": (None, [_bat(f * swing, swing) for f in (0.5, 2.0)]),
        "rack_backstop": (None, [core.TelemetryBackstop(
            critical_hz=(0.5, 1.0), window_s=2.0, sustain_s=0.5,
            amp_threshold_w=a * swing) for a in (0.05, 10.0)]),
        "rack_backstop_jnp": (None, [core.TelemetryBackstop(
            critical_hz=(0.5, 1.0), window_s=2.0, sustain_s=0.5,
            amp_threshold_w=a * swing, use_pallas=False)
            for a in (0.05, 10.0)]),
        "gpu_plus_battery": ([_gpu(m) for m in (0.5, 0.9)],
                             [_bat(f * swing, swing) for f in (0.5, 2.0)]),
    }


@pytest.mark.parametrize("name", ["device_gpu", "device_firefly",
                                  "rack_battery", "rack_backstop",
                                  "rack_backstop_jnp",
                                  "gpu_plus_battery"])
def test_simulate_batch_matches_simulate(name):
    dev, rack = _scenarios()[name]
    B = len(dev) if dev is not None else len(rack)
    tl = _timeline()
    # firefly's ballast quantization has ceil() decision boundaries that
    # f32/f64 EDP-spike rounding can flip; exact levels keep parity exact
    cfg = _cfg(jitter_s=0.002, edp_spikes=(name != "device_firefly"))
    spec = core.example_specs(job_mw=0.1)["moderate"]

    res = engine.simulate_batch(tl, N_CHIPS, cfg, device_mitigation=dev,
                                rack_mitigation=rack, spec=spec, seeds=3)
    assert len(res) == B
    for i in range(B):
        ref = core.simulate(
            tl, N_CHIPS, cfg,
            device_mitigation=dev[i] if dev is not None else None,
            rack_mitigation=rack[i] if rack is not None else None,
            spec=spec, seed=3)
        np.testing.assert_allclose(res.dc_raw[i], ref.dc_raw,
                                   rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(res.dc_mitigated[i], ref.dc_mitigated,
                                   rtol=1e-4, atol=1e-3)
        if dev is not None:
            np.testing.assert_allclose(res.chip_mitigated[i],
                                       ref.chip_mitigated,
                                       rtol=1e-5, atol=1e-3)
        for k, v in ref.swing_mitigated.items():
            np.testing.assert_allclose(res.swing_mitigated[k][i], v,
                                       rtol=1e-4, atol=1e-3, err_msg=k)
        for k, v in ref.bands_mitigated.items():
            np.testing.assert_allclose(res.bands_mitigated[k][i], v,
                                       rtol=5e-3, atol=2e-3, err_msg=k)
        np.testing.assert_allclose(res.energy_overhead[i],
                                   ref.energy_overhead, rtol=1e-3, atol=1e-6)
        # spec verdicts and violation sets agree exactly
        assert bool(res.spec_ok[i]) == ref.spec_report.ok
        assert res.report(i).violations == ref.spec_report.violations
        # the reconstructed per-scenario SimResult round-trips
        sr = res.scenario(i)
        assert sr.spec_report.ok == ref.spec_report.ok
        np.testing.assert_allclose(sr.dc_mitigated, ref.dc_mitigated,
                                   rtol=1e-4, atol=1e-3)


def test_simulate_batch_broadcasts_fleet_and_seeds():
    tl = _timeline()
    cfg = _cfg(jitter_s=0.002)
    fleets = [128, 512, 2048]
    res = engine.simulate_batch(tl, fleets, cfg, seeds=[0, 1, 2])
    for i, n in enumerate(fleets):
        ref = core.simulate(tl, n, cfg, seed=i)
        np.testing.assert_allclose(res.dc_raw[i], ref.dc_raw,
                                   rtol=1e-4, atol=1e-3)


def test_simulate_batch_mixes_enabled_and_disabled_rows():
    """Disabled (None) rows batch alongside enabled configs: the masked-off
    row reproduces the unmitigated serial run exactly."""
    tl = _timeline()
    cfg = _cfg(jitter_s=0.002)
    dc = _dc_wave()
    swing = float(dc.max() - dc.min())
    spec = core.example_specs(job_mw=0.1)["moderate"]
    dev = [_gpu(0.5), None, _gpu(0.9), None]
    rack = [_bat(swing, swing), _bat(2 * swing, swing), None, None]
    res = engine.simulate_batch(tl, N_CHIPS, cfg, device_mitigation=dev,
                                rack_mitigation=rack, spec=spec, seeds=3)
    for i in range(4):
        ref = core.simulate(tl, N_CHIPS, cfg, device_mitigation=dev[i],
                            rack_mitigation=rack[i], spec=spec, seed=3)
        np.testing.assert_allclose(res.dc_mitigated[i], ref.dc_mitigated,
                                   rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(res.energy_overhead[i],
                                   ref.energy_overhead, rtol=1e-3, atol=1e-6)
        assert bool(res.spec_ok[i]) == ref.spec_report.ok
        assert res.report(i).violations == ref.spec_report.violations
        # scenario() reflects the mask: no chip_mitigated and no
        # placeholder aux on disabled rows (the serial reference has none)
        sc = res.scenario(i)
        assert (sc.chip_mitigated is None) == (dev[i] is None)
        assert ("device" in sc.aux) == (dev[i] is not None)
        assert ("rack" in sc.aux) == (rack[i] is not None)


def test_simulate_batch_rejects_mixed_lengths():
    with pytest.raises(ValueError):
        engine.simulate_batch([_timeline(1.0), _timeline(2.0)],
                              N_CHIPS, _cfg())


# ---------------------------------------------------------------------------
# sweep: cartesian product, bucketed by waveform length
# ---------------------------------------------------------------------------

def test_sweep_buckets_mixed_length_workloads():
    workloads = {"short": _timeline(1.0), "long": _timeline(2.0, moe=True)}
    cfg = _cfg(jitter_s=0.002, steps=4)
    spec = core.example_specs(job_mw=0.1)["moderate"]
    dc = core.aggregate(core.chip_waveform(workloads["short"], cfg),
                        N_CHIPS, cfg)
    swing = float(dc.max() - dc.min())
    configs = [(_gpu(0.65), _bat(swing, swing)),
               (_gpu(0.9), _bat(2 * swing, swing))]
    recs = engine.sweep(workloads, [256, 512], configs, cfg, spec=spec)
    assert len(recs) == 2 * 2 * 2          # workloads x fleets x configs
    # record order follows the declared cartesian order despite bucketing
    assert [r["workload"] for r in recs] == ["short"] * 4 + ["long"] * 4
    for r in recs:
        ci, ni = r["config"], r["n_chips"]
        ref = core.simulate(workloads[r["workload"]], ni, cfg,
                            device_mitigation=configs[ci][0],
                            rack_mitigation=configs[ci][1], spec=spec)
        assert r["spec_ok"] == ref.spec_report.ok
        np.testing.assert_allclose(r["energy_overhead"], ref.energy_overhead,
                                   rtol=1e-3, atol=1e-6)


# ---------------------------------------------------------------------------
# batched design grid
# ---------------------------------------------------------------------------

def _serial_design_reference(spec, w, dt, n_chips, period_hint_s=2.0):
    """The pre-engine serial grid search, kept as the parity oracle."""
    swing = float(w.max() - w.min())
    mpf_grid = [0.0, 0.5, 0.65, 0.8, 0.9]
    cap_grid = [0.0] + [swing * period_hint_s * f for f in
                        (0.125, 0.25, 0.5, 1.0, 2.0)]
    for mpf in mpf_grid:
        for cap in cap_grid:
            gpu = _design_gpu(spec, mpf, n_chips) if mpf > 0 else None
            bat = (core.RackBattery(capacity_j=cap, max_discharge_w=swing,
                                    max_charge_w=swing) if cap > 0 else None)
            if gpu and bat:
                out, _ = core.CombinedMitigation(gpu, bat, n_chips).apply(w, dt)
            elif gpu:
                per_chip, _ = gpu.apply(w / n_chips, dt)
                out = per_chip * n_chips
            elif bat:
                out, _ = bat.apply(w, dt)
            else:
                out = w
            if spec.validate(out, dt).ok:
                return mpf, cap
    return None


def _design_gpu(spec, mpf, n_chips):
    return core.GpuPowerSmoothing(
        mpf_frac=mpf,
        ramp_up_w_per_s=spec.time.ramp_up_w_per_s / n_chips,
        ramp_down_w_per_s=spec.time.ramp_down_w_per_s / n_chips)


def test_design_mitigation_matches_serial_reference():
    tl = _timeline(period=2.0, comm=0.25)
    cfg = core.WaveformConfig(dt=0.002, steps=20, jitter_s=0.002)
    w = core.aggregate(core.chip_waveform(tl, cfg), N_CHIPS, cfg)
    spec = core.example_specs(job_mw=w.mean() / 1e6)["moderate"]
    sol = core.design_mitigation(spec, w, cfg.dt, N_CHIPS)
    assert sol is not None and sol["report"].ok
    ref = _serial_design_reference(spec, w, cfg.dt, N_CHIPS)
    assert ref is not None
    assert (sol["mpf_frac"], sol["battery_capacity_j"]) == pytest.approx(ref)


def test_design_grid_vmap_matches_per_candidate():
    """Each cell of the vmapped (MPF x capacity) grid equals the serial
    gated evaluation of that candidate."""
    tl = _timeline(period=2.0, comm=0.25)
    cfg = core.WaveformConfig(dt=0.002, steps=10, jitter_s=0.002)
    w = core.aggregate(core.chip_waveform(tl, cfg), N_CHIPS, cfg)
    spec = core.example_specs(job_mw=w.mean() / 1e6)["moderate"]
    swing = float(w.max() - w.min())
    mpf_grid, cap_grid = [0.0, 0.9], [0.0, 2.0 * swing]
    sol = engine.design_grid(spec, w, cfg.dt, N_CHIPS, mpf_grid, cap_grid,
                             swing=swing)
    grid_ok = (sol["grid_ok"] if sol is not None
               else np.zeros((2, 2), bool))
    for i, mpf in enumerate(mpf_grid):
        for j, cap in enumerate(cap_grid):
            gpu = _design_gpu(spec, mpf, N_CHIPS) if mpf > 0 else None
            bat = (core.RackBattery(capacity_j=cap, max_discharge_w=swing,
                                    max_charge_w=swing) if cap > 0 else None)
            if gpu and bat:
                out, _ = core.CombinedMitigation(gpu, bat, N_CHIPS).apply(
                    w, cfg.dt)
            elif gpu:
                per, _ = gpu.apply(w / N_CHIPS, cfg.dt)
                out = per * N_CHIPS
            elif bat:
                out, _ = bat.apply(w, cfg.dt)
            else:
                out = w
            assert bool(grid_ok[i, j]) == spec.validate(out, cfg.dt).ok, \
                (mpf, cap)


# ---------------------------------------------------------------------------
# aggregate jitter: edge padding, no wraparound
# ---------------------------------------------------------------------------

def test_aggregate_jitter_does_not_wrap_tail_to_head():
    cfg = core.WaveformConfig(dt=0.001, steps=1, jitter_s=0.02)
    lo, hi = 100.0, 200.0
    chip = np.concatenate([np.full(2000, lo), np.full(1000, hi)])
    agg = core.aggregate(chip, N_CHIPS, cfg, seed=0)
    scale = N_CHIPS * (1.0 + DEFAULT_HW.topo.distribution_loss)
    # head must see only the head level: a wrapping shift would leak the
    # hi tail into t=0 and lift it above lo
    np.testing.assert_allclose(agg[:100] / scale, lo, rtol=1e-6)
    # tail likewise holds its boundary level
    np.testing.assert_allclose(agg[-1] / scale, hi, rtol=1e-6)


def test_aggregate_jax_matches_numpy():
    from repro.core.waveform import aggregate_jax, jitter_shifts
    cfg = core.WaveformConfig(dt=0.001, steps=3, jitter_s=0.005)
    chip = core.chip_waveform(_timeline(), cfg)
    shifts = jitter_shifts(cfg, seed=7)
    ref = core.aggregate(chip, N_CHIPS, cfg, seed=7)
    out = np.asarray(aggregate_jax(np.asarray(chip, np.float32),
                                   float(N_CHIPS), shifts))
    np.testing.assert_allclose(out, ref, rtol=1e-5)

"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ballast.ballast import ballast_pallas
from repro.kernels.ballast.ops import ballast_burn, ballast_flops
from repro.kernels.ballast.ref import ballast_ref
from repro.kernels.goertzel.goertzel import goertzel_pallas
from repro.kernels.goertzel.ops import bin_power, sliding_bin_power
from repro.kernels.goertzel.ref import (bin_power_ref, goertzel_ref,
                                        sliding_bin_power_jnp,
                                        sliding_bin_power_ref)


@pytest.mark.parametrize("m,k,n", [(256, 128, 128), (512, 256, 256),
                                   (1024, 384, 384)])
@pytest.mark.parametrize("n_iter", [1, 7, 32])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ballast_vs_ref(m, k, n, n_iter, dtype):
    key = jax.random.PRNGKey(42)
    a = (jax.random.normal(key, (m, k), jnp.float32) / np.sqrt(k)).astype(dtype)
    b = (jnp.eye(k, n) * 0.999).astype(dtype)
    out = ballast_pallas(a, b, n_iter, interpret=True)
    ref = ballast_ref(a, b, n_iter)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("bm", [128, 256])
def test_ballast_block_shapes(bm):
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (512, 128), jnp.float32)
    b = (jnp.eye(128) * 0.999).astype(jnp.float32)
    out = ballast_pallas(a, b, 4, bm=bm, interpret=True)
    ref = ballast_ref(a, b, 4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_ballast_burn_hits_flop_target():
    assert ballast_flops(1024, 256, 256, 10) == 2 * 1024 * 256 * 256 * 10
    s = ballast_burn(jax.random.PRNGKey(0), gflops=0.02, interpret=True)
    assert np.isfinite(float(s))


@pytest.mark.parametrize("win", [256, 1000, 1024])
@pytest.mark.parametrize("n_freqs", [1, 3, 4])
def test_goertzel_vs_recurrence_ref(win, n_freqs):
    rng = np.random.default_rng(win + n_freqs)
    dt = 0.001
    n = win * 8
    x = rng.normal(100.0, 20.0, n).astype(np.float32)
    freqs = np.linspace(0.5, 10.0, n_freqs)
    out = bin_power(jnp.asarray(x), dt, jnp.asarray(freqs), win=win,
                    interpret=True)
    W = n // win
    coef = 2 * np.cos(2 * np.pi * freqs * dt)
    wnd = x[: W * win].reshape(W, win)
    wnd = wnd - wnd.mean(axis=1, keepdims=True)  # ops wrapper removes DC
    ref = goertzel_ref(wnd, coef)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=0.1)


def test_goertzel_recovers_known_amplitude():
    """A 30 W, 2 Hz oscillation must read ~30 on the 2 Hz bin."""
    dt = 0.001
    n = 8000
    t = np.arange(n) * dt
    x = 200 + 30 * np.sin(2 * np.pi * 2.0 * t)
    out = bin_power(jnp.asarray(x, jnp.float32), dt,
                    jnp.asarray([1.0, 2.0, 5.0]), win=1000, interpret=True)
    amps = np.asarray(out).mean(axis=0)
    assert abs(amps[1] - 30.0) < 1.5
    assert amps[0] < 3.0 and amps[2] < 3.0


def test_goertzel_matches_dft_at_integer_bins():
    dt = 0.001
    win = 1000  # 1 s -> integer Hz are exact DFT bins
    rng = np.random.default_rng(0)
    x = rng.normal(100, 15, win * 4).astype(np.float32)
    freqs = np.array([1.0, 3.0, 7.0])
    out = bin_power(jnp.asarray(x), dt, jnp.asarray(freqs), win=win,
                    interpret=True)
    ref = bin_power_ref(x.reshape(4, win), dt, freqs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-3, atol=0.05)


def test_goertzel_block_padding():
    """W not divisible by block_w exercises the pad/trim path."""
    dt = 0.001
    x = np.random.default_rng(1).normal(50, 5, 5 * 256).astype(np.float32)
    out = bin_power(jnp.asarray(x), dt, jnp.asarray([2.0]), win=256,
                    block_w=4, interpret=True)
    assert out.shape == (5, 1)
    assert np.all(np.isfinite(np.asarray(out)))


def test_bin_power_monitors_trailing_partial_window():
    """Regression: the trailing n % win samples used to be dropped — an
    oscillation confined to the tail of the trace went unmonitored."""
    dt = 0.001
    win = 1000
    n = 2500                       # 2 full windows + a 500-sample tail
    t = np.arange(n) * dt
    # 4 Hz = 2 integer cycles in the 0.5 s tail window
    x = 200.0 + np.where(t >= 2.0, 30.0 * np.sin(2 * np.pi * 4.0 * t), 0.0)
    out = np.asarray(bin_power(jnp.asarray(x, jnp.float32), dt,
                               jnp.asarray([4.0]), win=win, interpret=True))
    assert out.shape == (3, 1)     # ceil(n/win) rows, tail included
    assert abs(out[2, 0] - 30.0) < 1.5
    assert out[0, 0] < 3.0 and out[1, 0] < 3.0


def test_bin_power_trace_shorter_than_window():
    """n < win yields one partial window normalized by the true count."""
    dt = 0.001
    t = np.arange(500) * dt
    x = 100.0 + 20.0 * np.sin(2 * np.pi * 4.0 * t)   # 2 cycles in 0.5 s
    out = np.asarray(bin_power(jnp.asarray(x, jnp.float32), dt,
                               jnp.asarray([4.0]), win=1000, interpret=True))
    assert out.shape == (1, 1)
    assert abs(out[0, 0] - 20.0) < 1.0


# ---------------------------------------------------------------------------
# sliding Goertzel (telemetry backstop hot path)
# ---------------------------------------------------------------------------

def _mw_trace(n, dt, dc=5e8, amp=1e5):
    """MW-scale trace: a small oscillation riding on a huge DC offset."""
    t = np.arange(n) * dt
    return (dc + amp * np.sin(2 * np.pi * 1.0 * t)
            + 0.3 * amp * np.sin(2 * np.pi * 2.2 * t + 0.7))


@pytest.mark.parametrize("n,win", [(4096, 512), (3000, 512), (300, 512)])
@pytest.mark.parametrize("block_s", [1, 4])
def test_sliding_pallas_matches_f64_ref(n, win, block_s):
    """Pallas sliding kernel vs the float64 cumsum oracle on MW-scale
    traces with large DC (the f32 cancellation regression), fractional
    bins (0.39/2.2 Hz are non-integer cycles per window) and n < win."""
    dt = 0.01
    freqs = (0.39, 1.0, 2.2)
    x = _mw_trace(n, dt)
    ref = sliding_bin_power_ref(x, dt, np.asarray(freqs), win)
    out = np.asarray(sliding_bin_power(jnp.asarray(x, jnp.float32), dt,
                                       freqs, win=win, block_s=block_s,
                                       interpret=True))
    assert out.shape == (n, len(freqs))
    np.testing.assert_allclose(out, ref, atol=2e-3 * 1e5, rtol=2e-3)


def test_sliding_jnp_oracle_matches_f64_ref():
    """The corrected traced mirror agrees with the float64 oracle at MW
    scale (the pre-fix mirror did not remove the mean)."""
    dt = 0.01
    n, win = 8192, 1024
    freqs = (0.39, 1.0, 2.2)
    x = _mw_trace(n, dt)
    ref = sliding_bin_power_ref(x, dt, np.asarray(freqs), win)
    out = np.asarray(sliding_bin_power_jnp(jnp.asarray(x, jnp.float32), dt,
                                           freqs, win))
    np.testing.assert_allclose(out, ref, atol=5e-3 * 1e5, rtol=5e-3)


def _prefix_sliding_f32(x, dt, freqs, win):
    """The PRE-FIX estimator (kept inline to lock the regression): f32
    complex cumulative sums of the raw trace, no DC removal."""
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[-1]
    f = jnp.asarray(freqs, jnp.float32)
    t = jnp.arange(n, dtype=jnp.float32) * dt
    ph = jnp.exp(-2j * jnp.pi * t[:, None] * f[None, :])
    cs = jnp.cumsum(x[:, None] * ph, axis=0)
    w = jnp.concatenate([cs[:win], cs[win:] - cs[:-win]]) if n > win else cs
    denom = jnp.minimum(jnp.arange(n, dtype=jnp.float32) + 1.0, float(win))
    return 2.0 * jnp.abs(w) / denom[:, None]


def test_sliding_f32_cancellation_regression():
    """On a quiet 5e8 W trace the pre-fix estimator's warm-up reads ~2*DC
    for a full window (any threshold able to see a 1e5 W line is saturated
    by DC alone) and its post-warm-up 9 Hz floor sits at ~1e4 W; the fixed
    paths are numerically silent, so a 1e5 W line stays detectable."""
    dt = 0.005
    n = int(600.0 / dt)            # 10-minute trace
    win = int(8.0 / dt)
    freqs = (0.5, 1.0, 2.0, 9.0)
    x = jnp.asarray(np.full(n, 5e8), jnp.float32)

    old = np.asarray(_prefix_sliding_f32(x, dt, freqs, win))
    assert (old[:win].max(axis=1) > 5e4).mean() > 0.9   # warm-up saturated
    assert old[win:, 3].max() > 1e4                     # 9 Hz rounding floor

    fixed_jnp = np.asarray(sliding_bin_power_jnp(x, dt, freqs, win))
    fixed_pl = np.asarray(sliding_bin_power(x, dt, freqs, win=win,
                                            interpret=True))
    assert fixed_jnp.max() < 1e2
    assert fixed_pl.max() < 1e2


def test_sliding_pallas_vmaps():
    """The kernel composes with vmap (the batched engine's apply path):
    per-row results equal the serial call."""
    dt, win = 0.01, 256
    n = 1500
    rng = np.random.default_rng(0)
    # modest scale: MW numerics are covered above; at 5e8 W the f32 trace
    # mean itself differs by reduction order between vmapped and serial
    xs = 100.0 + 20.0 * rng.normal(size=(3, n))
    freqs = (0.5, 2.0)
    f = lambda x: sliding_bin_power(x, dt, freqs, win=win, interpret=True)
    batched = np.asarray(jax.vmap(f)(jnp.asarray(xs, jnp.float32)))
    for i in range(3):
        one = np.asarray(f(jnp.asarray(xs[i], jnp.float32)))
        np.testing.assert_allclose(batched[i], one, rtol=1e-6, atol=1e-3)


# ---------------------------------------------------------------------------
# sliding Goertzel v2: streamed carry + fused monitor
# ---------------------------------------------------------------------------

from repro.core.telemetry import (escalation_init, escalation_step,
                                  warmup_scale)
from repro.kernels.goertzel.goertzel import sliding_goertzel_pallas
from repro.kernels.goertzel.ops import (_phase_tables, monitor_carry_init,
                                        sliding_carry_init,
                                        sliding_monitor_fused, trace_mean)

#: uneven tick sizes: sub-window, window-crossing, 1-sample and partial ticks
_TICKS = [7, 250, 499, 500, 3, 711]


def _chunks(n, sizes):
    out, pos = [], 0
    for s in sizes:
        if pos >= n:
            break
        out.append((pos, min(pos + s, n)))
        pos += s
    if pos < n:
        out.append((pos, n))
    return out


def test_sliding_carry_bitwise_matches_offline():
    """Chunked carry calls concatenate *bitwise* to one offline call —
    both run the same v2 kernel program with the same streamed prefix
    state, so the parity is by construction, not by tolerance."""
    dt, win = 0.01, 500
    n = 4 * win + 123
    freqs = (0.39, 1.0, 2.2)
    x = np.asarray(_mw_trace(n, dt), np.float32)
    offline = np.asarray(sliding_bin_power(jnp.asarray(x), dt, freqs,
                                           win=win, interpret=True))
    carry = sliding_carry_init(dt, freqs, win=win,
                               mean=float(trace_mean(jnp.asarray(x))))
    outs = []
    for lo, hi in _chunks(n, _TICKS):
        amps, carry = sliding_bin_power(x[lo:hi], dt, freqs, win=win,
                                        interpret=True, carry=carry)
        outs.append(amps)
    np.testing.assert_array_equal(np.concatenate(outs, axis=0), offline)


def test_sliding_v1_matches_v2_layouts():
    """The retained v1 (bin-minor) A/B baseline kernel agrees with the
    lane-major v2 production path."""
    dt, win = 0.01, 500
    n = 3 * win
    freqs = (0.39, 1.0, 2.2)
    x = np.asarray(_mw_trace(n, dt), np.float32)
    v2 = np.asarray(sliding_bin_power(jnp.asarray(x), dt, freqs, win=win,
                                      interpret=True))
    cosp, sinp, rot = (jnp.asarray(t) for t in _phase_tables(freqs, dt, win))
    xc = jnp.asarray(x) - jnp.mean(jnp.asarray(x))
    raw = sliding_goertzel_pallas(xc.reshape(-1, win), cosp, sinp, rot,
                                  interpret=True)
    scale = warmup_scale(jnp.arange(n, dtype=jnp.float32), win)
    v1 = np.asarray(raw.reshape(n, len(freqs)) * scale[:, None])
    np.testing.assert_allclose(v1, v2, rtol=2e-6, atol=1e-2)


def test_monitor_fused_pallas_matches_jnp_mirror_bitwise():
    """Interpret-mode fused kernel == jitted jnp lax.scan mirror, bitwise
    (worst stream, escalation levels, detect index, window peaks)."""
    dt, win = 0.01, 500
    n = 2048
    freqs = (0.39, 1.0, 2.2)
    x = jnp.asarray(_mw_trace(n, dt), jnp.float32)
    kw = dict(win=win, threshold=6e4, release=5e4, sustain_n=50, cool_n=80,
              interpret=True)
    wp, lp, dp, pp = sliding_monitor_fused(x, dt, freqs, use_pallas=True,
                                           **kw)
    wj, lj, dj, pj = sliding_monitor_fused(x, dt, freqs, use_pallas=False,
                                           **kw)
    np.testing.assert_array_equal(np.asarray(wp), np.asarray(wj))
    np.testing.assert_array_equal(np.asarray(lp), np.asarray(lj))
    assert int(dp) == int(dj)
    np.testing.assert_array_equal(np.asarray(pp), np.asarray(pj))
    assert int(np.asarray(lp).max()) >= 1      # escalation actually fired
    assert int(dp) >= win - 1                  # and not off warm-up rows


def test_monitor_fused_matches_two_pass_escalation_step():
    """Fused in-kernel classification + blocked scan == the two-pass
    reference (materialize all amplitudes, fold ``escalation_step``
    sample by sample) — the shared-machine parity the fusion preserves."""
    dt, win = 0.01, 500
    n = 2048
    freqs = (0.39, 1.0, 2.2)
    x = jnp.asarray(_mw_trace(n, dt), jnp.float32)
    worst, levels, detect, _ = sliding_monitor_fused(
        x, dt, freqs, win=win, threshold=6e4, release=5e4,
        sustain_n=50, cool_n=80, interpret=True)
    amps = np.asarray(sliding_bin_power(x, dt, freqs, win=win,
                                        interpret=True))
    worst_ref = amps.max(axis=1)
    np.testing.assert_array_equal(np.asarray(worst), worst_ref)
    carry = escalation_init()
    ref_levels = []
    for i in range(n):
        carry, lvl = escalation_step(carry, jnp.float32(worst_ref[i]),
                                     jnp.int32(i), threshold=6e4,
                                     release=5e4, win=win, n=n,
                                     sustain_n=50, cool_n=80)
        ref_levels.append(int(lvl))
    np.testing.assert_array_equal(np.asarray(levels),
                                  np.asarray(ref_levels, np.int32))
    assert int(detect) == int(carry[3])


def test_monitor_fused_carry_bitwise_matches_offline():
    """Chunked fused monitor == offline fused monitor bitwise (worst and
    level streams, detect index), and the O(K) recombined ``amps_last``
    matches the materialized amplitudes at each chunk's last sample."""
    dt, win = 0.01, 500
    n = 2048
    freqs = (0.39, 1.0, 2.2)
    x = np.asarray(_mw_trace(n, dt), np.float32)
    kw = dict(win=win, threshold=6e4, release=5e4, sustain_n=50, cool_n=80,
              interpret=True)
    w_off, l_off, d_off, _ = sliding_monitor_fused(jnp.asarray(x), dt,
                                                   freqs, **kw)
    amps_off = np.asarray(sliding_bin_power(jnp.asarray(x), dt, freqs,
                                            win=win, interpret=True))
    carry = monitor_carry_init(dt, freqs, win=win,
                               mean=float(trace_mean(jnp.asarray(x))))
    ws, ls = [], []
    for lo, hi in _chunks(n, _TICKS):
        w, lv, amps_last, carry = sliding_monitor_fused(
            x[lo:hi], dt, freqs, carry=carry, **kw)
        ws.append(w)
        ls.append(lv)
        np.testing.assert_allclose(np.asarray(amps_last), amps_off[hi - 1],
                                   rtol=1e-6, atol=1e-3)
    np.testing.assert_array_equal(np.concatenate(ws), np.asarray(w_off))
    np.testing.assert_array_equal(np.concatenate(ls), np.asarray(l_off))
    assert int(carry.esc[3]) == int(d_off)


# ---------------------------------------------------------------------------
# flash attention (perf iteration #2)
# ---------------------------------------------------------------------------

from repro.kernels.flash.ops import flash_sdpa
from repro.kernels.flash.ref import flash_ref


@pytest.mark.parametrize("B,S,KV,G,D", [(1, 64, 2, 2, 16), (2, 128, 1, 4, 8),
                                        (1, 96, 3, 1, 32)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_vs_dense_oracle(B, S, KV, G, D, causal):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(B * S), 3)
    q = jax.random.normal(k1, (B, S, KV, G, D))
    k = jax.random.normal(k2, (B, S, KV, D))
    v = jax.random.normal(k3, (B, S, KV, D))
    out = flash_sdpa(q, k, v, causal=causal, q_block=32, kv_chunk=16,
                     interpret=True)
    ref = flash_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_mla_vdim():
    """V head dim != QK head dim (MLA layout)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(k1, (1, 64, 2, 1, 24))
    k = jax.random.normal(k2, (1, 64, 2, 24))
    v = jax.random.normal(k3, (1, 64, 2, 16))
    out = flash_sdpa(q, k, v, q_block=32, kv_chunk=16, interpret=True)
    assert out.shape == (1, 64, 2, 1, 16)
    ref = flash_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_bf16():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(k1, (1, 64, 2, 2, 16), jnp.bfloat16)
    k = jax.random.normal(k2, (1, 64, 2, 16), jnp.bfloat16)
    v = jax.random.normal(k3, (1, 64, 2, 16), jnp.bfloat16)
    out = flash_sdpa(q, k, v, q_block=32, kv_chunk=16, interpret=True)
    ref = flash_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=5e-2, atol=5e-2)

"""Decode-path correctness: token-by-token decode with a KV cache must
reproduce teacher-forced forward logits for every mixer family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import Model
from repro.models.model import Ctx

from conftest import tiny_batch

# one representative per mixer family keeps runtime sane
FAMILIES = ["granite-3-8b", "deepseek-v2-lite-16b", "jamba-v0.1-52b",
            "rwkv6-3b", "llama-3.2-vision-11b", "musicgen-medium"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    batch = tiny_batch(cfg, B=B, S=S)
    ctx = Ctx(cfg=cfg, vision_embeds=batch.get("vision_embeds"))

    # teacher-forced logits
    x, _ = m.forward(params, batch)
    full_logits = np.asarray(x @ params["lm_head"].astype(x.dtype))

    # token-by-token decode from scratch
    cache = m.init_cache(B, S, dtype=jnp.float32)
    decode_fn = m.decode_step()
    decode = jax.jit(lambda p, i, c, idx: decode_fn(p, i, c, idx, ctx))
    outs = []
    for i in range(S):
        if cfg.input_mode == "tokens":
            inp = batch["tokens"][:, i:i + 1]
        else:
            inp = batch["inputs"][:, i:i + 1]
        logits, cache = decode(params, inp, cache, jnp.asarray(i, jnp.int32))
        outs.append(np.asarray(logits[:, 0]))
    dec_logits = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec_logits, full_logits, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["granite-3-8b", "deepseek-v2-lite-16b"])
def test_prefill_then_decode(arch):
    """prefill(prompt) + decode(next) == forward over prompt+next."""
    cfg = reduced(get_config(arch))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, L = 2, 8
    batch = tiny_batch(cfg, B=B, S=L + 1)
    prompt = {k: (v[:, :L] if v.ndim >= 2 and v.shape[1] == L + 1 else v)
              for k, v in batch.items()}
    prompt.pop("labels")
    cache = m.init_cache(B, L + 1, dtype=jnp.float32)
    prefill = jax.jit(m.prefill())
    decode = jax.jit(m.decode_step())
    pl_logits, cache = prefill(params, prompt, cache)
    logits, cache = decode(params, batch["tokens"][:, L:L + 1], cache,
                           jnp.asarray(L, jnp.int32))
    x, _ = m.forward(params, {k: v for k, v in batch.items() if k != "labels"})
    ref = np.asarray((x @ params["lm_head"].astype(x.dtype)))
    np.testing.assert_allclose(np.asarray(pl_logits[:, 0]), ref[:, L - 1],
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(logits[:, 0]), ref[:, L],
                               rtol=2e-3, atol=2e-3)


def test_kv_repeat_equivalence():
    """kv-head duplication (TP layout) is a mathematical no-op."""
    cfg = reduced(get_config("granite-3-8b"))  # 4 heads, 2 kv heads
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = tiny_batch(cfg)
    x1, _ = m.forward(params, batch, Ctx(cfg=cfg, kv_repeat=1))
    x2, _ = m.forward(params, batch, Ctx(cfg=cfg, kv_repeat=2))
    np.testing.assert_allclose(np.asarray(x1), np.asarray(x2), rtol=1e-4, atol=1e-4)


def test_chunked_attention_matches_dense():
    cfg = reduced(get_config("granite-3-8b"))
    cfg_d = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, chunk_size=1 << 20))
    cfg_c = dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, chunk_size=8))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = tiny_batch(cfg, S=32)
    xd, _ = m.forward(params, batch, Ctx(cfg=cfg_d))
    xc, _ = m.forward(params, batch, Ctx(cfg=cfg_c))
    np.testing.assert_allclose(np.asarray(xd), np.asarray(xc), rtol=2e-4, atol=2e-4)


def test_remat_and_unroll_match_baseline():
    cfg = reduced(get_config("jamba-v0.1-52b"))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = tiny_batch(cfg)
    base = m.loss(params, batch, Ctx(cfg=cfg))[0]
    for kwargs in ({"remat": "full"}, {"remat": "dots"}, {"unroll": True}):
        alt = m.loss(params, batch, Ctx(cfg=cfg, **kwargs))[0]
        np.testing.assert_allclose(float(base), float(alt), rtol=1e-5)
    # grads under remat match too
    g1 = jax.grad(lambda p: m.loss(p, batch, Ctx(cfg=cfg))[0])(params)
    g2 = jax.grad(lambda p: m.loss(p, batch, Ctx(cfg=cfg, remat="full"))[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_loss_chunking_equivalence():
    cfg = reduced(get_config("granite-3-8b"))
    cfg_chunk = dataclasses.replace(cfg, loss_chunk=4)
    m1, m2 = Model(cfg), Model(cfg_chunk)
    params = m1.init(jax.random.PRNGKey(0))
    batch = tiny_batch(cfg, S=16)
    l1 = float(m1.loss(params, batch)[0])
    l2 = float(m2.loss(params, batch)[0])
    np.testing.assert_allclose(l1, l2, rtol=1e-5)


def test_q_chunked_attention_mla_vdim():
    """Regression: q-block path must use the V head dim (MLA 128 vs qk 192)."""
    import dataclasses as dc
    cfg = reduced(get_config("deepseek-v2-lite-16b"))
    cfg = dc.replace(cfg, attention=dc.replace(cfg.attention, chunk_size=8))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = tiny_batch(cfg, B=1, S=64)  # S > q_chunk path via small chunks
    from repro.models.attention import sdpa
    import repro.models.attention as A
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 2, 1, 24))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 2, 24))
    v = jax.random.normal(jax.random.PRNGKey(3), (1, 64, 2, 16))  # Dv != D
    pos = jnp.arange(64)
    out_q = sdpa(q, k, v, pos_q=pos, chunk=8, q_chunk=16)
    out_d = A._dense_sdpa(q, k, v, pos, jnp.arange(64), True, 24 ** -0.5)
    assert out_q.shape == (1, 64, 2, 1, 16)
    np.testing.assert_allclose(np.asarray(out_q), np.asarray(out_d),
                               rtol=1e-4, atol=1e-4)

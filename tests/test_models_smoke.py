"""Per-arch smoke tests (assignment requirement: reduced config of the same
family, one forward/train step on CPU, assert shapes + no NaNs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced, shapes_for
from repro.configs.base import LM_SHAPES
from repro.models import Model
from repro.train import init_train_state, make_train_step
from repro.configs import TrainConfig

from conftest import tiny_batch


def test_all_archs_registered():
    assert len(ARCH_IDS) == 10


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_validates(arch):
    cfg = get_config(arch)
    cfg.validate()
    n = cfg.param_count()
    # sanity: params within 40% of the advertised size class
    advertised = {"granite-3-8b": 8e9, "nemotron-4-340b": 340e9,
                  "qwen1.5-110b": 110e9, "minitron-4b": 4e9,
                  "musicgen-medium": 1.5e9, "deepseek-v2-lite-16b": 16e9,
                  "dbrx-132b": 132e9, "jamba-v0.1-52b": 52e9,
                  "rwkv6-3b": 3e9, "llama-3.2-vision-11b": 11e9}[arch]
    assert 0.6 * advertised < n < 1.6 * advertised, (arch, n, advertised)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = reduced(get_config(arch))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = tiny_batch(cfg)
    x, aux = jax.jit(lambda p, b: m.forward(p, b))(params, batch)
    assert x.shape == (2, 16, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(x)))
    # one train step
    tcfg = TrainConfig(total_steps=10)
    state = init_train_state(jax.random.PRNGKey(1), cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2.step) == 1
    # params actually changed
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(state.params),
                                jax.tree.leaves(state2.params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_moe_aux_present_for_moe_archs(arch):
    cfg = reduced(get_config(arch))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    loss, metrics = jax.jit(lambda p, b: m.loss(p, b))(params, tiny_batch(cfg))
    if cfg.moe is not None:
        assert float(metrics["moe_aux"]) > 0.0
    else:
        assert float(metrics["moe_aux"]) == 0.0


def test_shape_cells_inventory():
    """40 assigned cells; long_500k runs only for sub-quadratic archs."""
    cells = [(a, s.name) for a in ARCH_IDS for s in shapes_for(get_config(a))]
    assert len(cells) == 32  # 8 archs x 3 + 2 archs x 4 (skips in DESIGN.md)
    assert ("rwkv6-3b", "long_500k") in cells
    assert ("jamba-v0.1-52b", "long_500k") in cells
    assert ("granite-3-8b", "long_500k") not in cells
    assert len(LM_SHAPES) == 4

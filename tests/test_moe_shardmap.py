"""shard_map expert-parallel MoE (perf iteration #7) vs the dense oracle.

The multi-device check runs in a subprocess with 8 simulated host devices
(the main test process must keep the default 1-device platform)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import moe as moe_mod
from repro.models.model import Ctx


def test_shardmap_moe_single_device_degenerate():
    """On a (1,1) mesh the psum/all_gather are identities."""
    cfg = reduced(get_config("dbrx-132b"))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    key = jax.random.PRNGKey(0)
    p = moe_mod.init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model))
    ref = moe_mod.moe_forward_ref(p, x, cfg)
    ctx = Ctx(cfg=cfg, dropless=True)
    sm = (mesh, ("data",), ("data",), "model")
    out, aux = jax.jit(
        lambda p, x: moe_mod.moe_forward_shardmap(p, x, cfg, ctx, sm))(p, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config, reduced
    from repro.models import moe as moe_mod
    from repro.models.model import Ctx
    for arch in ("dbrx-132b", "deepseek-v2-lite-16b"):
        cfg = reduced(get_config(arch))
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        key = jax.random.PRNGKey(0)
        p = moe_mod.init_moe(key, cfg, jnp.float32)
        x = jax.random.normal(jax.random.fold_in(key, 1), (4, 8, cfg.d_model))
        ref = moe_mod.moe_forward_ref(p, x, cfg)
        ctx = Ctx(cfg=cfg, dropless=True)
        sm = (mesh, ("data",), ("data",), "model")
        out, aux = jax.jit(
            lambda p, x: moe_mod.moe_forward_shardmap(p, x, cfg, ctx, sm))(p, x)
        assert np.allclose(out, ref, rtol=2e-4, atol=2e-4), arch
        g = jax.grad(lambda p, x: moe_mod.moe_forward_shardmap(
            p, x, cfg, ctx, sm)[0].sum())(p, x)
        gn = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(g))
        assert gn > 0 and np.isfinite(gn), arch
    print("MULTIDEV_OK")
""")


def test_shardmap_moe_multidevice_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", SUBPROC], env=env,
                       capture_output=True, text=True, timeout=480,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert "MULTIDEV_OK" in r.stdout, r.stdout + r.stderr


DP_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from repro.configs import TrainConfig, get_config, reduced
    from repro.data import SyntheticLM
    from repro.train import init_train_state, make_train_step
    from repro.train.trainer import make_dp_compressed_train_step
    cfg = reduced(get_config("granite-3-8b"))
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=5, total_steps=30)
    mesh = jax.make_mesh((8,), ("data",))
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    step_c, init_err = make_dp_compressed_train_step(cfg, tcfg, mesh)
    err = init_err(state.params)
    step_c = jax.jit(step_c)
    ref_state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    ref_step = jax.jit(make_train_step(cfg, tcfg))
    data = SyntheticLM(cfg, batch=8, seq=32, seed=0)
    for i in range(30):
        b = {k: jnp.asarray(v) for k, v in data(i).items()}
        state, err, m = step_c(state, err, b)
        ref_state, mr = ref_step(ref_state, b)
    lc, lr = float(m["loss"]), float(mr["loss"])
    assert lc < 4.0, lc                      # converged
    assert abs(lc - lr) < 0.4, (lc, lr)      # tracks exact training
    print("DP_COMPRESSED_OK")
""")


def test_dp_compressed_training_subprocess():
    """int8 error-feedback gradient all-reduce: 8-way DP training converges
    and tracks the exact-gradient trajectory (3.9x less DP wire)."""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", DP_SUBPROC], env=env,
                       capture_output=True, text=True, timeout=480,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert "DP_COMPRESSED_OK" in r.stdout, r.stdout + r.stderr

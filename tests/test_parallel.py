"""Sharding-plan correctness for every arch on the production mesh shapes —
validated WITHOUT devices: divisibility of every sharded dim against a
16x16 / 2x16x16 mesh, caught at test time instead of dry-run time."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.model import init_params
from repro.parallel.collectives import compressed_allreduce_mean
from repro.parallel.sharding import (Plan, batch_pspecs, cache_pspecs,
                                     make_plan, param_pspecs)


class FakeMesh:
    """Carries axis names/sizes for spec computation (no devices needed)."""

    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape, dtype=object)


def _plans(cfg):
    for shape, names in (((16, 16), ("data", "model")),
                         ((2, 16, 16), ("pod", "data", "model"))):
        yield make_plan(cfg, FakeMesh(shape, names))


def _axis_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _check_divisible(struct, specs, mesh, where):
    sizes = _axis_sizes(mesh)
    for (path, leaf), (_, spec) in zip(
            jax.tree_util.tree_flatten_with_path(struct)[0],
            jax.tree_util.tree_flatten_with_path(specs)[0]):
        assert len(spec) <= len(leaf.shape), (where, path, spec, leaf.shape)
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            denom = 1
            for a in axes:
                denom *= sizes[a]
            assert dim % denom == 0, (where, path, leaf.shape, spec)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divisible_on_production_meshes(arch):
    cfg = get_config(arch)
    params_s = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    for plan in _plans(cfg):
        specs = param_pspecs(cfg, plan, params_s)
        sizes = _axis_sizes(plan.mesh)
        for (path, leaf), (_, spec) in zip(
                jax.tree_util.tree_flatten_with_path(params_s)[0],
                jax.tree_util.tree_flatten_with_path(specs)[0]):
            for dim, entry in zip(leaf.shape, tuple(spec)):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                denom = 1
                for a in axes:
                    denom *= sizes[a]
                if dim % denom:
                    # uneven sharding is allowed only for the vocab axis
                    # (GSPMD pads); everything else must divide exactly
                    assert dim == cfg.vocab_size, (arch, path, leaf.shape, spec)


@pytest.mark.parametrize("arch", ["granite-3-8b", "deepseek-v2-lite-16b",
                                  "jamba-v0.1-52b", "rwkv6-3b"])
def test_cache_specs_divisible(arch):
    from repro.models.model import init_cache
    cfg = get_config(arch)
    cache_s = jax.eval_shape(lambda: init_cache(cfg, 128, 32768, jnp.bfloat16))
    for plan in _plans(cfg):
        specs = cache_pspecs(cfg, plan, cache_s, batch_size=128)
        _check_divisible(cache_s, specs, plan.mesh, arch)


def test_attn_mode_selection():
    sizes = {"granite-3-8b": ("heads", 2), "nemotron-4-340b": ("heads", 2),
             "qwen1.5-110b": ("heads", 2), "deepseek-v2-lite-16b": ("heads", 1),
             "minitron-4b": ("replicated", 1), "musicgen-medium": ("replicated", 1)}
    for arch, (mode, r) in sizes.items():
        cfg = get_config(arch)
        plan = make_plan(cfg, FakeMesh((16, 16), ("data", "model")))
        assert plan.attn_mode == mode, arch
        assert plan.kv_repeat == r, arch


def test_compressed_allreduce_single_device():
    """On one device psum is identity: checks quantize+error-feedback algebra."""
    def run(x, err):
        return compressed_allreduce_mean(x, err, "i")

    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (1, 512)), jnp.float32)
    e0 = jnp.zeros_like(x)
    mean, err = jax.vmap(run, axis_name="i")(x, e0)
    # quantization error small and captured in err
    np.testing.assert_allclose(np.asarray(mean + err), np.asarray(x),
                               rtol=1e-5, atol=1e-5)
    # error feedback: applying twice with carried error reduces bias
    mean2, _ = jax.vmap(run, axis_name="i")(x, err)
    np.testing.assert_allclose(np.asarray(mean2), np.asarray(x), atol=6e-2)
    # and the two-step average is strictly better than one-shot quantization
    avg = (np.asarray(mean) + np.asarray(mean2)) / 2
    assert np.abs(avg - np.asarray(x)).mean() <= np.abs(
        np.asarray(mean) - np.asarray(x)).mean() + 1e-6


def test_fsdp_excludes_pod_axis():
    cfg = get_config("granite-3-8b")
    plan = make_plan(cfg, FakeMesh((2, 16, 16), ("pod", "data", "model")))
    assert plan.dp == ("pod", "data")
    assert plan.fsdp == ("data",)  # weight gathers never cross pods

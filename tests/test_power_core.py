"""Unit tests: spec validation, spectrum, waveform synthesis, phases."""
import numpy as np
import pytest

import repro.core as core


def square_wave(period_s=2.0, duty=0.75, hi=220.0, lo=90.0, dt=0.001, secs=60):
    n = int(secs / dt)
    t = np.arange(n) * dt
    return np.where((t % period_s) < duty * period_s, hi, lo), dt


# ---------------------------------------------------------------------------
def test_spectrum_peak_at_iteration_frequency():
    w, dt = square_wave(period_s=2.0)
    assert abs(core.dominant_frequency(w, dt) - 0.5) < 0.05


def test_band_energy_concentered_in_paper_band():
    """Paper: FFT energy concentrated 0.2-3 Hz for 1-5 s iterations."""
    for period in (0.5, 1.0, 3.0):
        w, dt = square_wave(period_s=period)
        frac = core.band_energy_fraction(w, dt, 0.2, 3.0)
        assert frac > 0.5, (period, frac)


def test_flat_load_has_no_band_energy():
    w = np.full(10000, 1e6)
    assert core.band_energy_fraction(w, 0.001, 0.1, 20.0) == 0.0


# ---------------------------------------------------------------------------
def test_spec_validate_flags_violations():
    w, dt = square_wave(hi=1e6, lo=0.4e6)
    spec = core.UtilitySpec(
        "tight",
        core.TimeDomainSpec(ramp_up_w_per_s=1e5, ramp_down_w_per_s=1e5,
                            dynamic_range_w=1e5),
        core.FrequencyDomainSpec((0.1, 20.0), 0.1))
    rep = spec.validate(w, dt)
    assert not rep.ok
    assert "ramp_up" in rep.violations
    assert "dynamic_range" in rep.violations
    assert "band_energy" in rep.violations


def test_validate_jax_reports_zero_dynamic_range_on_one_window():
    """A waveform exactly one sliding window long: the numpy path's strided
    loop never runs and reports dynamic_range_w=0.0 — the traced mirror
    must report the same metric instead of dropping it."""
    import jax.numpy as jnp
    spec = core.example_specs(job_mw=1.0)["moderate"]
    dt = 0.001
    n = int(spec.time.window_s / dt)     # exactly one window
    w = 1e6 + 1e5 * np.sin(2 * np.pi * 5.0 * np.arange(n) * dt)
    rep = spec.validate(w, dt)
    assert rep.metrics["dynamic_range_w"] == 0.0
    ok, flags, metrics = spec.validate_jax(jnp.asarray(w, jnp.float32), dt)
    assert "dynamic_range_w" in metrics
    assert float(metrics["dynamic_range_w"]) == 0.0
    assert not bool(flags["dynamic_range"])
    assert set(metrics) == set(rep.metrics)
    assert bool(ok) == rep.ok


def test_spec_validate_passes_smooth_load():
    n = 60000
    w = 1e6 + 1e3 * np.sin(2 * np.pi * 0.01 * np.arange(n) * 0.001)
    spec = core.example_specs(job_mw=1.0)["tight"]
    rep = spec.validate(w, 0.001)
    assert rep.ok, rep.violations


# ---------------------------------------------------------------------------
def test_phase_timeline_from_cell():
    cell = {"n_chips": 256,
            "exact": {"flops": 7.5e16, "bytes": 1.0e16},
            "collectives": {"all-reduce": 7e11},
            "memory": {"state_bytes_per_device": 8e9}}
    tl = core.from_dryrun_cell(cell)
    assert tl.period_s > 0
    modes = [p.mode for p in tl.phases]
    assert "comm" in modes
    # moe cell adds the all-to-all notch
    cell["collectives"]["all-to-all"] = 2e11
    tl2 = core.from_dryrun_cell(cell)
    assert any(p.name == "moe-a2a" for p in tl2.phases)


def test_chip_waveform_levels_and_edp():
    tl = core.synthetic_timeline(period_s=1.0, comm_frac=0.3)
    cfg = core.WaveformConfig(dt=0.001, steps=5, edp_spikes=True)
    w = core.chip_waveform(tl, cfg)
    hw = core.DEFAULT_HW
    assert w.min() == pytest.approx(hw.chip.comm_w)
    assert w.max() == pytest.approx(hw.chip.tdp_w * hw.chip.edp_factor)
    # EDP overshoot limited to the 50 ms window
    over = (w > hw.chip.tdp_w + 1).sum() * cfg.dt
    assert over <= 5 * (hw.chip.edp_window_s + 0.002)


def test_aggregate_scales_and_jitter_softens():
    tl = core.synthetic_timeline(period_s=1.0, comm_frac=0.3)
    cfg0 = core.WaveformConfig(dt=0.001, steps=6, jitter_s=0.0, edp_spikes=False)
    cfgj = core.WaveformConfig(dt=0.001, steps=6, jitter_s=0.02, edp_spikes=False)
    w0 = core.aggregate(core.chip_waveform(tl, cfg0), 512, cfg0)
    wj = core.aggregate(core.chip_waveform(tl, cfgj), 512, cfgj)
    assert w0.max() > 512 * 200  # ~512 chips near TDP
    # jitter preserves mean but softens the extremes
    assert abs(wj.mean() - w0.mean()) / w0.mean() < 0.02
    assert wj.max() <= w0.max() + 1e-6
    # swing survives jitter (bulk-synchronous job): still a large fraction
    assert (wj.max() - wj.min()) > 0.5 * (w0.max() - w0.min())


def test_server_breakdown_matches_fig2_claim():
    """Fig. 2: accelerators are >50% of provisioned server power."""
    assert core.DEFAULT_HW.chip_share() > 0.5


# ---------------------------------------------------------------------------
def test_stagger_meets_ramp_limit():
    rack_w = 32 * 220.0
    limit = 2 * rack_w  # W/s
    sched = core.plan_stagger(n_racks=16, rack_power_w=rack_w,
                              ramp_limit_w_per_s=limit, rack_ramp_s=2.0)
    w = core.ramp_waveform(sched, 16, rack_w, dt=0.01)
    assert core.max_ramp(w, 0.01) <= limit * 1.05
    # and the unstaggered ramp would violate it
    flat = core.StaggerSchedule(offsets_s=np.zeros(16),
                                rack_ramp_w_per_s=sched.rack_ramp_w_per_s)
    w_bad = core.ramp_waveform(flat, 16, rack_w, dt=0.01)
    assert core.max_ramp(w_bad, 0.01) > limit

"""Hypothesis property tests on system invariants.

``hypothesis`` is an optional test dependency (``pip install -e .[test]``);
without it this module skips instead of failing collection.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import repro.core as core
from repro.core.hardware import DEFAULT_HW
from repro.models.layers import apply_rope
from repro.parallel.collectives import BLOCK, compressed_bytes, quantize_roundtrip

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# int8 error-feedback compression
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.integers(1, 3000), st.integers(0, 2 ** 31 - 1),
       st.floats(1e-6, 1e6))
def test_quantize_roundtrip_error_bounded(n, seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, scale, n), jnp.float32)
    y = quantize_roundtrip(x)
    # per-block error bounded by half a quantization step
    err = np.abs(np.asarray(x - y))
    blocks = np.abs(np.asarray(x))
    pad = (-n) % BLOCK
    bmax = np.pad(blocks, (0, pad)).reshape(-1, BLOCK).max(axis=1)
    bound = np.repeat(bmax / 127.0 * 0.5001 + 1e-10, BLOCK)[:n]
    assert np.all(err <= bound)


@settings(**SETTINGS)
@given(st.integers(1, 10 ** 6))
def test_compressed_bytes_below_fp32(n):
    assert compressed_bytes(n) < 4 * n or n < 16


# ---------------------------------------------------------------------------
# power controllers
# ---------------------------------------------------------------------------

def _wave(seed, n=4000, dt=0.001):
    rng = np.random.default_rng(seed)
    levels = rng.uniform(DEFAULT_HW.chip.idle_w, DEFAULT_HW.chip.tdp_w, 8)
    seg = n // 8
    return np.repeat(levels, seg)[:n].astype(np.float64)


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.4, 0.9),
       st.floats(100.0, 5000.0))
def test_gpu_floor_ramp_invariant(seed, mpf, ramp):
    w = _wave(seed)
    gf = core.GpuPowerSmoothing(mpf_frac=mpf, ramp_up_w_per_s=ramp,
                                ramp_down_w_per_s=ramp, stop_delay_s=1.0)
    out, _ = gf.apply(w, 0.001)
    d = np.abs(np.diff(out)) / 0.001
    assert d.max() <= ramp * 1.01 + 1e-6
    assert out.max() <= DEFAULT_HW.chip.tdp_w * DEFAULT_HW.chip.edp_factor + 1e-6
    assert out.min() >= 0.0


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.8, 1.0),
       st.floats(0.1, 4.0))
def test_battery_soc_and_energy_invariants(seed, eff, capf):
    w = _wave(seed) * 100
    swing = max(w.max() - w.min(), 1.0)
    bat = core.RackBattery(capacity_j=capf * swing, max_discharge_w=swing,
                           max_charge_w=swing, efficiency=eff)
    out, aux = bat.apply(w, 0.001)
    soc = aux["soc_trace"]
    assert soc.min() >= -1e-3 and soc.max() <= capf * swing * (1 + 1e-6)
    assert np.all(out >= -1e-6)
    # exact bookkeeping identity: SoC trajectory = integral of (dis, chg)
    # flows with one-way efficiency — energy is never created in the update
    dt = 0.001
    flows = w - out                         # >0: discharge, <0: charge
    dis = np.clip(flows, 0.0, None)
    chg = np.clip(-flows, 0.0, None)
    soc0 = 0.5 * capf * swing
    expected = soc0 - dis.sum() * dt / eff + chg.sum() * dt * eff
    np.testing.assert_allclose(soc[-1], expected,
                               rtol=5e-3, atol=1e-2 * capf * swing + 1.0)
    # and the battery never delivers more than efficiency allows round-trip
    assert dis.sum() * dt <= eff * (soc0 + chg.sum() * dt * eff) + 1.0


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1))
def test_firefly_never_exceeds_tdp_nor_reduces_power(seed):
    w = _wave(seed)
    ff = core.Firefly()
    out, _ = ff.apply(w, 0.001)
    assert out.max() <= DEFAULT_HW.chip.tdp_w + 1e-6
    assert np.all(out >= w - 1e-6)  # ballast only ever adds power


# ---------------------------------------------------------------------------
# spectrum / stagger
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1))
def test_band_fractions_partition(seed):
    w = _wave(seed)
    lo = core.band_energy_fraction(w, 0.001, 0.0, 5.0)
    hi = core.band_energy_fraction(w, 0.001, 5.0001, 500.0)  # disjoint bins
    assert 0.0 <= lo <= 1.0 and 0.0 <= hi <= 1.0
    assert lo + hi <= 1.0 + 1e-6


@settings(**SETTINGS)
@given(st.integers(2, 64), st.floats(1e4, 1e6), st.floats(0.2, 3.0))
def test_stagger_always_meets_limit(n_racks, rack_w, mult):
    limit = mult * rack_w  # W/s
    sched = core.plan_stagger(n_racks, rack_w, limit, rack_ramp_s=1.0)
    w = core.ramp_waveform(sched, n_racks, rack_w, dt=0.02)
    assert core.max_ramp(w, 0.02) <= limit * 1.10


# ---------------------------------------------------------------------------
# model numerics
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(st.integers(1, 4), st.integers(1, 16), st.integers(1, 8),
       st.integers(0, 2 ** 31 - 1))
def test_rope_is_isometry(b, s, d2, seed):
    d = 2 * d2
    x = jax.random.normal(jax.random.PRNGKey(seed), (b, s, 1, d))
    pos = jnp.arange(s)
    y = apply_rope(x, pos[None, :, None], 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 2), st.sampled_from([8, 12, 16]),
       st.sampled_from([4, 8]), st.integers(0, 2 ** 31 - 1))
def test_chunked_attention_property(b, s, chunk, seed):
    from repro.models.attention import _chunked_sdpa, _dense_sdpa
    if s % chunk:
        return
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (b, s, 2, 2, 8))
    k = jax.random.normal(k2, (b, s, 2, 8))
    v = jax.random.normal(k3, (b, s, 2, 8))
    pos = jnp.arange(s)
    dense = _dense_sdpa(q, k, v, pos, jnp.arange(s), True, 8 ** -0.5)
    chnk = _chunked_sdpa(q, k, v, pos, True, 8 ** -0.5, chunk)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chnk),
                               rtol=1e-4, atol=1e-4)

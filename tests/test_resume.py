"""Resumable checkpointed streaming: kill-and-resume bit-parity,
append-extension without recompute, and the loud corruption paths
(truncated checkpoint, fingerprint mismatch, chunk-size mismatch).

The reference results come from uninterrupted runs of the same Study;
every resumed/extended run must equal them record-for-record — the PR-5
invariant (per-row values are chunk-composition independent) is what
makes restoring some chunks from disk and computing the rest exact.
"""
import glob
import os
import shutil

import numpy as np
import pytest

import repro.core as core
from repro.ckpt import ResumeError, load_pytree_numpy, save_pytree
from repro.ckpt.resume import SweepCheckpoint, record_positions, rows_chain

STREAM = 4


def _study(extra_workload=False, seeds=(0, 1)):
    wl = {"w": core.synthetic_timeline(1.0, 0.3),
          "w2": core.synthetic_timeline(2.0, 0.25, moe_notch=True)}
    if extra_workload:
        wl["w3"] = core.synthetic_timeline(1.5, 0.2)
    gpu = lambda m: core.GpuPowerSmoothing(
        mpf_frac=m, ramp_up_w_per_s=2000, ramp_down_w_per_s=2000,
        stop_delay_s=1.0)
    return core.Study(
        wl, fleets=[128],
        configs={"none": None, "a": (gpu(0.8), None), "b": (gpu(0.65), None)},
        specs=core.example_specs(job_mw=0.05)["moderate"],
        wave_cfg=core.WaveformConfig(dt=0.002, steps=3, jitter_s=0.002),
        key=0, seeds=list(seeds))


@pytest.fixture(scope="module")
def ref():
    return _study().run(stream=STREAM).to_records()


@pytest.fixture(scope="module")
def ref_ext():
    return _study(extra_workload=True).run(stream=STREAM).to_records()


class Kill(Exception):
    """Stand-in for SIGKILL at a chunk boundary (the subprocess-level
    kill is exercised by ``sweep_bench --resume-smoke`` in CI)."""


def test_fresh_run_with_resume_dir_matches_plain(tmp_path, ref):
    d = str(tmp_path / "ck")
    got = _study().run(stream=STREAM, resume=d)
    assert got.to_records() == ref
    assert os.path.exists(os.path.join(d, "sweep.json"))
    assert glob.glob(os.path.join(d, "chunks", "*", "chunk_*"))


def test_kill_mid_stream_then_resume_is_bit_identical(tmp_path, ref):
    d = str(tmp_path / "ck")

    def die_after_two(done, total, elapsed):
        if done >= 2 * STREAM:
            raise Kill

    with pytest.raises(Kill):
        _study().run(stream=STREAM, resume=d, on_chunk=die_after_two)
    survivors = glob.glob(os.path.join(d, "chunks", "*", "chunk_*"))
    assert survivors, "kill before any checkpoint was written"

    calls = []
    got = _study().run(stream=STREAM, resume=d,
                       on_chunk=lambda dn, t, e: calls.append((dn, t)))
    assert got.to_records() == ref
    # first emission reports the restored prefix in one global jump
    assert calls[0][0] >= 2 * STREAM and calls[0][1] == calls[-1][0]


def test_complete_restore_recomputes_nothing(tmp_path, ref):
    d = str(tmp_path / "ck")
    _study().run(stream=STREAM, resume=d)
    saved = {p: os.path.getmtime(p) for p in
             glob.glob(os.path.join(d, "chunks", "*", "chunk_*"))}
    calls = []
    got = _study().run(stream=STREAM, resume=d,
                       on_chunk=lambda dn, t, e: calls.append((dn, t)))
    assert got.to_records() == ref
    # one emission per call stream, covering everything; no chunk rewritten
    assert calls == [(12, 12)]
    assert {p: os.path.getmtime(p) for p in saved} == saved


def test_extension_computes_only_new_rows(tmp_path, ref_ext):
    d = str(tmp_path / "ck")
    _study().run(stream=STREAM, resume=d)
    n_old_chunks = len(glob.glob(os.path.join(d, "chunks", "*", "chunk_*")))
    calls = []
    got = _study(extra_workload=True).run(
        stream=STREAM, resume=d,
        on_chunk=lambda dn, t, e: calls.append((dn, t)))
    assert got.to_records() == ref_ext
    # the old 12 rows arrive as one restored prefix; only w3's 6 rows run
    assert calls[0] == (12, 18)
    assert len(calls) == 1 + (6 + STREAM - 1) // STREAM
    assert len(glob.glob(os.path.join(d, "chunks", "*", "chunk_*"))) \
        > n_old_chunks


def test_truncated_checkpoint_fails_loudly(tmp_path):
    d = str(tmp_path / "ck")
    _study().run(stream=STREAM, resume=d)
    victim = sorted(glob.glob(
        os.path.join(d, "chunks", "*", "chunk_*", "*.npy")))[0]
    with open(victim, "r+b") as fh:
        fh.truncate(8)
    with pytest.raises(ResumeError, match="corrupt chunk checkpoint"):
        _study().run(stream=STREAM, resume=d)


def test_grid_fingerprint_mismatch_fails_loudly(tmp_path):
    d = str(tmp_path / "ck")
    _study().run(stream=STREAM, resume=d)
    with pytest.raises(ResumeError, match="fingerprint mismatch"):
        _study(seeds=(5, 6)).run(stream=STREAM, resume=d)
    # shrinking the grid is not an extension either
    with pytest.raises(ResumeError, match="extended, not shrunk"):
        _study(seeds=(0,)).run(stream=STREAM, resume=d)


def test_chunk_size_mismatch_fails_loudly(tmp_path):
    d = str(tmp_path / "ck")
    _study().run(stream=STREAM, resume=d)
    with pytest.raises(ResumeError, match=f"stream={STREAM}"):
        _study().run(stream=STREAM + 2, resume=d)


def test_resume_requires_streaming_and_no_waveforms(tmp_path):
    d = str(tmp_path / "ck")
    with pytest.raises(ValueError, match="requires streaming"):
        _study().run(resume=d)
    s = _study()
    s.keep_waveforms = True
    with pytest.raises(ValueError, match="keep_waveforms"):
        s.run(stream=STREAM, resume=d)


def test_unreadable_sweep_manifest_fails_loudly(tmp_path):
    d = str(tmp_path / "ck")
    _study().run(stream=STREAM, resume=d)
    with open(os.path.join(d, "sweep.json"), "w") as fh:
        fh.write("{not json")
    with pytest.raises(ResumeError, match="unreadable sweep manifest"):
        _study().run(stream=STREAM, resume=d)


# ---------------------------------------------------------------------------
# unit level: fingerprints, positions, object-dtype checkpoint leaves
# ---------------------------------------------------------------------------

def test_rows_chain_prefix_semantics():
    wl = {"w": core.synthetic_timeline(1.0, 0.3)}
    cfgs = core.MitigationConfig("none")
    rows = [("w", 128, cfgs, s) for s in range(5)]
    full = rows_chain(wl, rows, None, at=[3, 5])
    pre = rows_chain(wl, rows[:3], None, at=[3])
    assert full[3] == pre[3]
    assert full[5] != full[3]
    other = rows_chain(wl, rows[:2] + [("w", 256, cfgs, 2)] + rows[3:],
                       None, at=[3])
    assert other[3] != full[3]


def test_record_positions_interleave():
    assert list(record_positions(np.asarray([2, 5]), 3)) \
        == [6, 7, 8, 15, 16, 17]


def test_object_dtype_checkpoint_roundtrip(tmp_path):
    cols = np.empty(3, dtype=object)
    cols[0], cols[1], cols[2] = {"a": 1.5}, ("x", "y"), None
    tree = {"cols": {"metrics": cols}, "rows": np.arange(3)}
    d = str(tmp_path / "step")
    save_pytree(d, tree, step=0)
    leaves, manifest = load_pytree_numpy(d)
    assert manifest["leaves"]["cols/metrics"]["object"] is True
    got = leaves["cols/metrics"]
    assert got[0] == {"a": 1.5} and got[1] == ("x", "y") and got[2] is None
    assert np.array_equal(leaves["rows"], np.arange(3))

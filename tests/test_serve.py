"""Serving engine: batched generation, determinism, MoE dropless decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import Model
from repro.serve import ServeEngine


@pytest.mark.parametrize("arch", ["granite-3-8b", "deepseek-v2-lite-16b"])
def test_generate_greedy_deterministic(arch):
    cfg = reduced(get_config(arch))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, L, G = 2, 8, 6
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0, cfg.vocab_size)
    eng1 = ServeEngine(cfg, params, max_seq=L + G + 1, batch=B)
    eng2 = ServeEngine(cfg, params, max_seq=L + G + 1, batch=B)
    out1 = eng1.generate(prompts, G)
    out2 = eng2.generate(prompts, G)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (B, G)


def test_generate_matches_teacher_forcing():
    """First generated token == argmax of forward logits at the last
    prompt position."""
    cfg = reduced(get_config("granite-3-8b"))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, L = 2, 8
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0, cfg.vocab_size)
    eng = ServeEngine(cfg, params, max_seq=L + 4, batch=B)
    out = eng.generate(prompts, 1)
    x, _ = m.forward(params, {"tokens": prompts})
    ref = jnp.argmax((x @ params["lm_head"].astype(x.dtype))[:, -1], axis=-1)
    np.testing.assert_array_equal(np.asarray(out[:, 0]), np.asarray(ref))


def test_sampling_temperature():
    cfg = reduced(get_config("granite-3-8b"))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, L = 2, 8
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0, cfg.vocab_size)
    eng = ServeEngine(cfg, params, max_seq=L + 10, batch=B)
    out = eng.generate(prompts, 8, temperature=1.5, key=jax.random.PRNGKey(7))
    assert out.shape == (B, 8)
    assert int(out.max()) < cfg.vocab_size

"""PowerComplianceService concurrency + amortization: true-LRU answer
cache, single-flight dedup of identical in-flight queries, coalesced
``query_many``/``handle_many`` parity, memoized workload features, and
compiled-executable reuse across query shapes."""
import json
import threading

import numpy as np
import pytest

import repro.core as core
from repro.core import engine
from repro.serve.power import PowerComplianceService


CFG = core.WaveformConfig(dt=0.01, steps=3, jitter_s=0.01)


def _service(**kw):
    kw.setdefault("wave_cfg", CFG)
    kw.setdefault("mpf_grid", (0.8,))
    kw.setdefault("cap_fracs", (1.0,))
    kw.setdefault("stream_chunk", 4)
    return PowerComplianceService(**kw)


def _tl(period_s=1.0, comm_frac=0.25, moe=False):
    return core.synthetic_timeline(period_s=period_s, comm_frac=comm_frac,
                                   moe_notch=moe)


# -- LRU ---------------------------------------------------------------------

def test_lru_caps_resident_entries_and_evicts_oldest():
    svc = _service(cache_size=2)
    a, b, c = _tl(1.0), _tl(1.4), _tl(0.7)
    svc.query(a, 512)
    svc.query(b, 512)
    svc.query(a, 512)              # refresh a: b is now the LRU entry
    svc.query(c, 512)              # evicts b, not a
    assert svc.cache_len() == 2
    assert svc.stats["evictions"] == 1
    runs = svc.stats["study_runs"]
    svc.query(a, 512)              # still cached
    assert svc.stats["study_runs"] == runs
    svc.query(b, 512)              # evicted: must re-run
    assert svc.stats["study_runs"] == runs + 1


def test_cache_hit_is_same_answer_without_rerun():
    svc = _service()
    first = svc.query(_tl(), 512)
    again = svc.query(_tl(), 512)
    assert again == first
    assert svc.stats == dict(svc.stats, hits=1, misses=1, study_runs=1)


# -- single-flight -----------------------------------------------------------

def test_concurrent_identical_queries_run_study_once():
    svc = _service()
    n, results, errs = 8, [None] * 8, []

    def hammer(i):
        try:
            results[i] = svc.query(_tl(), 512)
        except Exception as e:      # pragma: no cover - surfaced below
            errs.append(e)

    threads = [threading.Thread(target=hammer, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs, errs
    assert svc.stats["study_runs"] == 1
    assert svc.stats["misses"] == 1
    assert all(r == results[0] for r in results)
    # cache stays consistent afterwards
    assert svc.query(_tl(), 512) == results[0]


# -- coalescing --------------------------------------------------------------

def test_query_many_coalesces_and_matches_serial():
    serial = _service()
    ans = [serial.query(_tl(1.0), 512, "moderate"),
           serial.query(_tl(1.4), 1024, "lenient"),
           serial.query(_tl(0.7, moe=True), 2048, "tight")]
    assert serial.stats["study_runs"] == 3

    co = _service()
    got = co.query_many([
        {"workload": _tl(1.0), "n_chips": 512, "spec": "moderate"},
        {"workload": _tl(1.4), "n_chips": 1024, "spec": "lenient"},
        {"workload": _tl(0.7, moe=True), "n_chips": 2048, "spec": "tight"},
    ])
    assert co.stats["study_runs"] == 1
    for a, b in zip(ans, got):
        a = dict(a, workload=None)          # names differ; physics must not
        b = dict(b, workload=None)
        assert json.dumps(a, default=float, sort_keys=True) == \
            json.dumps(b, default=float, sort_keys=True)


def test_query_many_duplicates_and_hits():
    svc = _service()
    first = svc.query(_tl(1.0), 512)
    got = svc.query_many([
        {"workload": _tl(1.0), "n_chips": 512},    # cache hit
        {"workload": _tl(1.4), "n_chips": 512},    # miss (leads)
        {"workload": _tl(1.4), "n_chips": 512},    # duplicate of the miss
    ])
    assert got[0] == first
    assert got[1] == got[2]
    assert svc.stats["study_runs"] == 2            # first + one coalesced


def test_handle_many_json_boundary():
    svc = _service()
    out = svc.handle_many([
        {"workload": {"period_s": 1.0, "comm_frac": 0.25}, "n_chips": 256},
        {"workload": "garbage", "n_chips": 1},
        {"workload": {"period_s": 1.3, "comm_frac": 0.3}, "n_chips": 128},
    ])
    assert "error" in out[1]
    assert out[0]["n_chips"] == 256 and out[2]["n_chips"] == 128
    assert out[0] == svc.handle(
        {"workload": {"period_s": 1.0, "comm_frac": 0.25}, "n_chips": 256})


# -- memoized features -------------------------------------------------------

def test_feature_memo_skips_recompute():
    svc = _service()
    tl = _tl()
    spec = core.example_specs(job_mw=1.0)["moderate"]
    f1 = svc._features(tl, 512, spec)
    f2 = svc._features(tl, 512, spec)
    assert svc.stats["feature_misses"] == 1
    assert svc.stats["feature_hits"] == 1
    np.testing.assert_array_equal(f1, f2)
    # a different fleet is a different fingerprint
    svc._features(tl, 1024, spec)
    assert svc.stats["feature_misses"] == 2


def test_workload_memo_reuses_synthesis():
    svc = _service()
    tl = _tl()
    s1 = svc._workload_state(tl)
    s2 = svc._workload_state(tl)
    assert s1 is s2
    a1 = svc._fleet_state(tl, 512)
    a2 = svc._fleet_state(tl, 512)
    assert a1 is a2


# -- compiled reuse ----------------------------------------------------------

def test_no_retrace_across_fleets_and_spec_thresholds():
    svc = _service()
    tl = _tl()
    svc.query(tl, 512, "moderate")
    n_exec = engine._mitigate_vmapped._cache_size()
    svc.query(tl, 1024, "lenient")
    svc.query(tl, 4096, "tight")
    svc.query_many([{"workload": tl, "n_chips": 256, "spec": s}
                    for s in ("moderate", "lenient")])
    assert engine._mitigate_vmapped._cache_size() == n_exec, \
        "new fleet sizes / spec thresholds retraced the pipeline"

"""Mitigation-stack behaviour tests (paper Sec. IV)."""
import dataclasses

import numpy as np
import pytest

import repro.core as core
from repro.core.hardware import DEFAULT_HW

DT = 0.001
TDP = DEFAULT_HW.chip.tdp_w


def chip_square(period=2.0, duty=0.75, secs=30, lo=None):
    lo = DEFAULT_HW.chip.comm_w if lo is None else lo
    n = int(secs / DT)
    t = np.arange(n) * DT
    return np.where((t % period) < duty * period, TDP, lo)


# ---------------------------------------------------------------------------
# GPU power smoothing (Sec. IV-B)
# ---------------------------------------------------------------------------

def test_gpu_floor_holds_mpf():
    w = chip_square()
    gf = core.GpuPowerSmoothing(mpf_frac=0.9, ramp_up_w_per_s=5000,
                                ramp_down_w_per_s=5000, stop_delay_s=10.0)
    out, aux = gf.apply(w, DT)
    # after the first rise, power never drops below MPF (stop delay long)
    first_hi = np.argmax(w >= TDP) + 100
    assert out[first_hi:].min() >= 0.9 * TDP - 1e-3
    assert aux["energy_overhead"] > 0


def test_gpu_floor_respects_ramp_rates():
    w = chip_square()
    ru, rd = 800.0, 400.0
    gf = core.GpuPowerSmoothing(mpf_frac=0.65, ramp_up_w_per_s=ru,
                                ramp_down_w_per_s=rd, stop_delay_s=0.5)
    out, _ = gf.apply(w, DT)
    d = np.diff(out) / DT
    assert d.max() <= ru * 1.001
    assert d.min() >= -rd * 1.001


def test_gpu_floor_stop_delay_then_rampdown():
    """Fig. 5 phases: steady -> stop delay at MPF -> ramp down."""
    n = int(10 / DT)
    w = np.full(n, DEFAULT_HW.chip.idle_w)
    w[: n // 2] = TDP  # workload ends at t=5s
    gf = core.GpuPowerSmoothing(mpf_frac=0.65, ramp_up_w_per_s=2000,
                                ramp_down_w_per_s=200, stop_delay_s=1.0,
                                activity_threshold_frac=0.5)
    out, _ = gf.apply(w, DT)
    t_end = n // 2
    hold = out[t_end + 100: t_end + int(0.9 / DT)]
    assert np.all(hold >= 0.65 * TDP - 1e-3)  # floor held during stop delay
    # by 2.5s after stop delay the ramp-down has pulled power well below MPF
    later = out[t_end + int(3.5 / DT):]
    assert later.min() < 0.4 * TDP


def test_mpf_energy_overhead_monotonic_in_floor():
    w = chip_square()
    overheads = []
    for mpf in (0.5, 0.65, 0.8, 0.9):
        gf = core.GpuPowerSmoothing(mpf_frac=mpf, ramp_up_w_per_s=5000,
                                    ramp_down_w_per_s=5000, stop_delay_s=10.0)
        _, aux = gf.apply(w, DT)
        overheads.append(aux["energy_overhead"])
    assert all(b >= a - 1e-9 for a, b in zip(overheads, overheads[1:]))


def test_mpf_capped_at_90_percent():
    with pytest.raises(AssertionError):
        core.GpuPowerSmoothing(mpf_frac=0.95)


# ---------------------------------------------------------------------------
# Battery (Sec. IV-C)
# ---------------------------------------------------------------------------

def test_battery_smooths_and_conserves():
    w = chip_square() * 1000  # rack-ish scale
    swing = w.max() - w.min()
    bat = core.RackBattery(capacity_j=swing * 4, max_discharge_w=swing,
                           max_charge_w=swing, efficiency=1.0,
                           target_tau_s=5.0)
    out, aux = bat.apply(w, DT)
    assert (out.max() - out.min()) < 0.35 * swing
    # exact conservation at efficiency 1.0
    soc = aux["soc_trace"]
    e_in, e_out = w.sum() * DT, out.sum() * DT
    np.testing.assert_allclose(e_out, e_in + (soc[-1] - soc[0]), rtol=1e-3)
    assert 0.0 <= aux["soc_min_frac"] <= aux["soc_max_frac"] <= 1.0


def test_battery_lossy_never_creates_energy():
    w = chip_square() * 1000
    swing = w.max() - w.min()
    bat = core.RackBattery(capacity_j=swing * 4, max_discharge_w=swing,
                           max_charge_w=swing, efficiency=0.9)
    out, aux = bat.apply(w, DT)
    soc = aux["soc_trace"]
    # grid energy + battery drawdown must cover the load (losses >= 0)
    e_grid = out.sum() * DT
    e_load = w.sum() * DT
    assert e_grid + (soc[0] - soc[-1]) / 0.9 >= e_load - 1e-3 * e_load


def test_battery_capacity_limits_bite():
    w = chip_square() * 1000
    swing = w.max() - w.min()
    small = core.RackBattery(capacity_j=swing * 0.05, max_discharge_w=swing,
                             max_charge_w=swing)
    out, aux = small.apply(w, DT)
    # too small to remove the swing
    assert (out.max() - out.min()) > 0.5 * swing


# ---------------------------------------------------------------------------
# Firefly (Sec. IV-A)
# ---------------------------------------------------------------------------

def test_firefly_fills_valleys_to_target():
    w = chip_square()
    ff = core.Firefly(engage_frac=0.85, threshold_frac=0.8)
    out, aux = ff.apply(w, DT)
    # valleys filled except telemetry/backoff gaps
    valley = out[(w < 100)]
    frac_filled = (valley >= 0.84 * TDP).mean()
    assert frac_filled > 0.9
    assert aux["energy_overhead"] > 0.05
    assert aux["perf_overhead"] < 0.05  # paper: <5%


def test_firefly_reaches_full_tdp():
    """Paper: 'Firefly was able to increase utilization up to 100% of TDP'."""
    w = chip_square()
    ff = core.Firefly(engage_frac=1.0, threshold_frac=0.95)
    out, aux = ff.apply(w, DT)
    assert aux["reaches_tdp_frac"] >= 0.999


def test_firefly_slow_telemetry_misses_fast_swings():
    """Paper: 100 ms counters are too slow for 20 Hz swings."""
    n = int(10 / DT)
    t = np.arange(n) * DT
    w = np.where((t % 0.05) < 0.025, TDP, DEFAULT_HW.chip.comm_w)  # 20 Hz
    fast = core.Firefly(telemetry=core.TelemetrySource(period_s=0.001,
                                                       latency_s=0.001))
    slow = core.Firefly(telemetry=core.TelemetrySource(period_s=0.1,
                                                       latency_s=0.1))
    out_f, _ = fast.apply(w, DT)
    out_s, _ = slow.apply(w, DT)
    res_f = core.band_energy_fraction(out_f, DT, 15, 25)
    res_s = core.band_energy_fraction(out_s, DT, 15, 25)
    assert res_f < res_s  # fast telemetry suppresses the 20 Hz line better


# ---------------------------------------------------------------------------
# Backstop (Sec. IV-E) + combined (Sec. IV-D)
# ---------------------------------------------------------------------------

def test_backstop_detects_and_escalates():
    n = int(60 / DT)
    t = np.arange(n) * DT
    base = 50e6
    w = base + np.where(t > 20, 8e6 * np.sign(np.sin(2 * np.pi * 2.0 * t)), 0.0)
    bs = core.TelemetryBackstop(critical_hz=(1.0, 2.0, 3.0), window_s=4.0,
                                amp_threshold_w=4e6, sustain_s=2.0)
    out, aux = bs.apply(w, DT)
    assert aux["max_level"] >= 1
    assert 20.0 < aux["detect_latency_s"] < 35.0
    # response attenuates the resonant line
    pre = core.band_energy_fraction(w[int(25 / DT):], DT, 1.5, 2.5)
    post = core.band_energy_fraction(out[int(25 / DT):], DT, 1.5, 2.5)
    assert post < pre


def test_backstop_quiet_load_untouched():
    w = np.full(int(30 / DT), 50e6)
    bs = core.TelemetryBackstop(amp_threshold_w=1e6)
    out, aux = bs.apply(w, DT)
    assert aux["max_level"] == 0
    np.testing.assert_array_equal(out, w)


def _prefix_backstop_max_level(w, dt, freqs, window_s, thr, sustain_s):
    """PRE-FIX monitor replica (estimator without DC removal + state
    machine without the warm-up gate), kept inline to lock the regression."""
    import jax.numpy as jnp
    w32 = jnp.asarray(w, jnp.float32)
    n = len(w)
    win = max(int(window_s / dt), 8)
    f = jnp.asarray(freqs, jnp.float32)
    t = jnp.arange(n, dtype=jnp.float32) * dt
    ph = jnp.exp(-2j * jnp.pi * t[:, None] * f[None, :])
    cs = jnp.cumsum(w32[:, None] * ph, axis=0)
    acc = jnp.concatenate([cs[:win], cs[win:] - cs[:-win]]) if n > win else cs
    denom = np.minimum(np.arange(n) + 1, win)
    worst = np.asarray(2.0 * jnp.abs(acc)).max(axis=1) / denom
    sustain_n = max(int(sustain_s / dt), 1)
    level = above = 0
    for hit in worst > thr:
        above = above + 1 if hit else 0
        if hit and above >= sustain_n and level < 3:
            level, above = level + 1, 0
    return level


def test_backstop_detects_mw_scale_oscillation():
    """Acceptance regression: a 1e5 W oscillation riding on a 5e8 W DC
    offset over a 10-minute f32 trace.  The fixed backstop (both the jnp
    oracle and the Pallas kernel path) stays quiet on the DC-only trace
    and detects the oscillation with the right latency.  The pre-fix
    sliding path provably misses it: its partial warm-up windows read
    ~2*DC at every usable threshold, so the monitor escalates on the
    QUIET trace — no threshold both rejects a quiet MW trace and sees a
    1e5 W line."""
    dt = 0.002
    n = int(600.0 / dt)
    t = np.arange(n) * dt
    quiet = np.full(n, 5e8, np.float32)
    onset = 300.0
    signal = (5e8 + np.where(t >= onset,
                             1e5 * np.sin(2 * np.pi * 2.0 * t), 0.0))
    freqs = (0.5, 1.0, 2.0, 9.0)
    for use_pallas in (False, True):
        bs = core.TelemetryBackstop(critical_hz=freqs, window_s=8.0,
                                    amp_threshold_w=5e4, sustain_s=1.5,
                                    use_pallas=use_pallas)
        _, aux_q = bs.apply(quiet, dt)
        assert aux_q["max_level"] == 0, f"false positive (pallas={use_pallas})"
        _, aux_s = bs.apply(signal, dt)
        assert aux_s["max_level"] >= 1, f"missed signal (pallas={use_pallas})"
        # detection after onset + window fill + sustain, not at warm-up
        assert onset < aux_s["detect_latency_s"] < onset + 15.0
    # the pre-fix monitor escalates on the quiet trace => provably cannot
    # separate the 1e5 W signal from a quiet MW trace at this threshold
    assert _prefix_backstop_max_level(quiet, dt, freqs, 8.0, 5e4, 1.5) >= 1


def test_backstop_fused_scan_matches_kernel_and_oracle():
    """The fused amps->escalation scan (one lax.scan over window-sized
    segments; the [n, K] amplitude matrix never exists) implements the
    same hop-and-overlap math as the Pallas sliding kernel: identical
    ``worst_bin_amp`` stream and escalation trace, and the same verdicts
    as the separate-pass cumsum oracle — including on a tail that is not
    a whole number of windows."""
    import jax.numpy as jnp
    dt = 0.002
    n = int(45.0 / dt) + 7                   # non-multiple of win
    t = np.arange(n) * dt
    w = (50e6 + np.where(t > 15, 6e6 * np.sin(2 * np.pi * 2.0 * t), 0.0)
         ).astype(np.float32)
    base = core.TelemetryBackstop(critical_hz=(0.5, 1.0, 2.0), window_s=4.0,
                                  amp_threshold_w=3e6, sustain_s=1.0,
                                  use_pallas=False)
    fused = base                                       # fused_scan defaults on
    kernel = dataclasses.replace(base, use_pallas=True)
    oracle = dataclasses.replace(base, fused_scan=False)
    out_f, aux_f = fused.apply_jax(jnp.asarray(w), dt)
    out_k, aux_k = kernel.apply_jax(jnp.asarray(w), dt)
    out_o, aux_o = oracle.apply_jax(jnp.asarray(w), dt)
    # same segment-restarted prefix-sum math as the kernel: bit-level match
    np.testing.assert_array_equal(np.asarray(aux_f["worst_bin_amp"]),
                                  np.asarray(aux_k["worst_bin_amp"]))
    np.testing.assert_array_equal(np.asarray(aux_f["levels"]),
                                  np.asarray(aux_k["levels"]))
    np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_k))
    # verdict parity with the cumsum-oracle reference path (the two
    # estimators round differently near threshold crossings, so the
    # escalation trace may shift by a sample — the detection verdict,
    # latency and amplitude stream must agree)
    assert int(aux_f["max_level"]) == int(aux_o["max_level"]) >= 1
    np.testing.assert_allclose(float(aux_f["detect_latency_s"]),
                               float(aux_o["detect_latency_s"]), atol=0.1)
    np.testing.assert_allclose(np.asarray(aux_f["worst_bin_amp"]),
                               np.asarray(aux_o["worst_bin_amp"]),
                               rtol=5e-3, atol=200.0)


def test_backstop_warmup_spike_does_not_escalate():
    """A spike at t=0 must not trigger escalation off partial-window
    amplitude estimates: no level change before one full window has
    streamed (and none at all — the spike's full-window amplitude is
    small)."""
    dt = 0.002
    n = int(30.0 / dt)
    w = np.full(n, 50e6, np.float32)
    w[:25] += 4e7                            # hard spike at t=0
    bs = core.TelemetryBackstop(window_s=8.0, amp_threshold_w=1e6,
                                sustain_s=0.2, use_pallas=False)
    win = int(8.0 / dt)
    for use_pallas in (False, True):
        bs = dataclasses.replace(bs, use_pallas=use_pallas)
        out, aux = bs.apply(w, dt)
        assert aux["levels"][:win].max() == 0, \
            f"escalated during warm-up (pallas={use_pallas})"
        assert aux["max_level"] == 0
        np.testing.assert_array_equal(out, w)


def test_design_mitigation_finds_passing_combo():
    tl = core.synthetic_timeline(period_s=2.0, comm_frac=0.25)
    cfg = core.WaveformConfig(dt=0.002, steps=20, jitter_s=0.002)
    n_chips = 512
    w = core.aggregate(core.chip_waveform(tl, cfg), n_chips, cfg)
    spec = core.example_specs(job_mw=w.mean() / 1e6)["moderate"]
    sol = core.design_mitigation(spec, w, cfg.dt, n_chips)
    assert sol is not None
    assert sol["report"].ok
    # must not be maximally wasteful: solver prefers low-MPF solutions
    assert sol["energy_overhead"] < 0.5

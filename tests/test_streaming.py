"""Streaming chunked executor + columnar StudyResult + mesh sharding plan.

The acceptance contract (ISSUE 5): chunked runs are bit-identical to
one-shot runs on overlapping grids — including mixed-length padded
groups whose chunk boundaries split a dedup prefix group — the columnar
record store answers the query API exactly like the list-of-dicts form,
and scenario-axis sharding (the mesh-general plan) composes with
chunking.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import repro.core as core
from repro.core import engine
from repro.core.study import StudyResult
from repro.parallel.sharding import ScenarioShardPlan, scenario_plan

DT = 0.002
N_CHIPS = 256


def _tl(period=1.0, comm=0.3, moe=False):
    return core.synthetic_timeline(period_s=period, comm_frac=comm,
                                   moe_notch=moe)


def _cfg(**kw):
    kw.setdefault("dt", DT)
    kw.setdefault("steps", 4)
    kw.setdefault("jitter_s", 0.002)
    return core.WaveformConfig(**kw)


def _gpu(mpf):
    return core.GpuPowerSmoothing(mpf_frac=mpf, ramp_up_w_per_s=2000,
                                  ramp_down_w_per_s=2000, stop_delay_s=1.0)


def _noisy_firefly():
    return core.Firefly(telemetry=core.TelemetrySource(
        period_s=0.002, latency_s=0.002, noise_w=20.0))


def _study(**kw):
    """Mixed-length workloads, a disabled baseline, a noisy config, two
    specs, two seeds: every fusion/dedup/keying lever active at once."""
    cfg = _cfg()
    tl_short, tl_long = _tl(1.0), _tl(2.0, moe=True)
    dc = core.aggregate(core.chip_waveform(tl_short, cfg), N_CHIPS, cfg)
    swing = float(dc.max() - dc.min())
    bat = core.RackBattery(capacity_j=swing, max_discharge_w=swing,
                           max_charge_w=swing, target_tau_s=5.0)
    specs = core.example_specs(job_mw=dc.mean() / 1e6)
    kw.setdefault("configs", {"none": None,
                              "mpf80+bat": (_gpu(0.8), bat),
                              "noisy_ff": (_noisy_firefly(), None)})
    return core.Study(
        {"short": tl_short, "long": tl_long}, fleets=[N_CHIPS],
        specs={"moderate": specs["moderate"], "tight": specs["tight"]},
        seeds=[0, 1], wave_cfg=cfg, key=0, **kw)


# ---------------------------------------------------------------------------
# chunked == one-shot, bitwise
# ---------------------------------------------------------------------------

def test_chunked_padded_run_is_bit_identical_to_oneshot():
    """Padded (mixed-length fused) groups, chunk size 5: boundaries fall
    inside structure groups AND split dedup prefix groups (rows sharing a
    (workload, fleet, seed) synthesis prefix sit at stride len(seeds)=2,
    so a 5-row chunk always cuts one).  Records must be bit-identical."""
    study = _study()
    oneshot = study.run(padding="pad")
    chunked = study.run(padding="pad", stream=5)
    assert len(chunked) == len(oneshot) == 24
    assert chunked.records == oneshot.records


def test_chunked_bucket_run_is_bit_identical_to_oneshot():
    study = _study()
    oneshot = study.run(padding="bucket")
    chunked = study.run(padding="bucket", stream=2)
    assert chunked.records == oneshot.records


def test_chunk_size_one_and_overshoot_match():
    study = _study(configs={"none": None, "mpf80": (_gpu(0.8), None)})
    ref = study.run()
    assert study.run(stream=1).records == ref.records       # 1 row per chunk
    assert study.run(stream=10_000).records == ref.records  # chunk > grid
    assert study.run(stream=True).records == ref.records


def test_chunked_waveforms_match_oneshot():
    study = _study(keep_waveforms=True)
    a = study.run()
    b = study.run(stream=3)
    assert b.waveforms is not None and len(b.waveforms) == len(a.waveforms)
    for wa, wb in zip(a.waveforms, b.waveforms):
        np.testing.assert_array_equal(wa["dc_mitigated"], wb["dc_mitigated"])
        np.testing.assert_array_equal(wa["dc_raw"], wb["dc_raw"])


def test_on_chunk_progress_reports_done_total_elapsed():
    study = _study()
    calls = []
    study.run(stream=4, on_chunk=lambda d, t, e: calls.append((d, t, e)))
    assert calls[-1][0] == calls[-1][1] == study.n_rows
    done = [d for d, _, _ in calls]
    assert done == sorted(done) and len(set(done)) == len(done)
    elapsed = [e for _, _, e in calls]
    assert all(b >= a for a, b in zip(elapsed, elapsed[1:]))
    assert all(t == study.n_rows for _, t, _ in calls)


# ---------------------------------------------------------------------------
# engine.stream_batches directly
# ---------------------------------------------------------------------------

def test_stream_batches_matches_simulate_batch_metrics():
    """Uniform-length rows, one spec: chunk metrics must equal the
    one-shot engine call's in-jit reductions."""
    cfg = _cfg()
    tl = _tl(1.0)
    dc = core.aggregate(core.chip_waveform(tl, cfg), N_CHIPS, cfg)
    swing = float(dc.max() - dc.min())
    spec = core.example_specs(job_mw=dc.mean() / 1e6)["moderate"]
    mits = [_gpu(m) for m in (0.5, 0.65, 0.8, 0.9, 0.85)]
    ref = engine.simulate_batch(tl, N_CHIPS, cfg, device_mitigation=mits,
                                spec=spec, seeds=[0, 1, 2, 3, 4])
    chunks = list(engine.stream_batches(tl, N_CHIPS, cfg,
                                        device_mitigation=mits, specs=spec,
                                        seeds=[0, 1, 2, 3, 4], chunk_size=2))
    assert [len(c) for c in chunks] == [2, 2, 1]
    assert [(c.start, c.stop) for c in chunks] == [(0, 2), (2, 4), (4, 5)]
    eo = np.concatenate([c.energy_overhead for c in chunks])
    np.testing.assert_array_equal(eo, ref.energy_overhead)
    ok = np.concatenate([c.spec_ok[0] for c in chunks])
    np.testing.assert_array_equal(ok, ref.spec_ok)
    swing_mit = np.concatenate([c.swing_mitigated["swing_w"] for c in chunks])
    np.testing.assert_array_equal(swing_mit, ref.swing_mitigated["swing_w"])
    for b, (c, j) in enumerate([(0, 0), (0, 1), (1, 0), (1, 1), (2, 0)]):
        rep = chunks[c].report(0, j)
        assert rep.ok == bool(ref.spec_ok[b])
        assert rep.violations == ref.report(b).violations
        for k, v in ref.report(b).metrics.items():
            np.testing.assert_allclose(rep.metrics[k], v, rtol=1e-6,
                                       atol=1e-9, err_msg=k)


def test_stream_batches_mixed_lengths_and_waveforms():
    """Mixed lengths auto-pad; per-row true lengths survive; waveforms
    only come back when explicitly requested."""
    cfg = _cfg()
    tls = [_tl(1.0), _tl(2.0, moe=True), _tl(1.0)]
    lens = [len(core.chip_waveform(t, cfg)) for t in tls]
    chunks = list(engine.stream_batches(tls, N_CHIPS, cfg,
                                        device_mitigation=_gpu(0.8),
                                        specs=None, chunk_size=2))
    got = [c.length(i) for c in chunks for i in range(len(c))]
    assert got == lens
    assert all(c.dc_mitigated is None and c.dc_raw is None for c in chunks)
    assert all(c.spec_ok == [None] for c in chunks)
    assert all(c.bands_mitigated is not None for c in chunks)

    kept = list(engine.stream_batches(tls, N_CHIPS, cfg,
                                      device_mitigation=_gpu(0.8),
                                      specs=None, chunk_size=2,
                                      keep_waveforms=True))
    ref = engine.simulate_batch(tls, N_CHIPS, cfg, device_mitigation=_gpu(0.8),
                                pad_to=max(lens), spectra=False)
    rows = np.concatenate([c.dc_mitigated for c in kept])
    np.testing.assert_array_equal(rows, ref.dc_mitigated)


# ---------------------------------------------------------------------------
# columnar StudyResult: API parity with the list-of-dicts form
# ---------------------------------------------------------------------------

def test_columnar_roundtrip_matches_list_of_dicts(tmp_path):
    res = _study().run()
    legacy = StudyResult(records=[dict(r) for r in res.records])

    assert res.to_records() == legacy.to_records()
    assert res.to_json() == legacy.to_json()
    assert res.to_csv() == legacy.to_csv()
    assert res.table() == legacy.table()
    assert len(res) == len(legacy)
    assert res[3] == legacy[3] and list(res) == list(legacy)

    for where in ({"workload": "short"},
                  {"config": ["none", "mpf80+bat"], "seed": 0},
                  {"spec": "tight", "spec_ok": True},
                  {"designed": False},
                  {"no_such_field": None}):
        a, b = res.filter(**where), legacy.filter(**where)
        assert a.records == b.records, where
    assert res.passing().records == legacy.passing().records
    assert res.failing().records == legacy.failing().records
    assert res.best() == legacy.best()
    assert res.best(among_passing=False) == legacy.best(among_passing=False)
    assert res.unique("config") == legacy.unique("config")
    assert res.passing_configs() == legacy.passing_configs()
    for piv in (("workload", "config", "spec_ok"),
                ("workload", "config", "energy_overhead")):
        assert res.pivot(*piv) == legacy.pivot(*piv)

    # filtered columnar subsets stay queryable and keep python types
    sub = res.filter(workload="short").filter(seed=0)
    assert all(r["workload"] == "short" and r["seed"] == 0 for r in sub)
    rec = sub[0]
    assert isinstance(rec["n_chips"], int)
    assert isinstance(rec["energy_overhead"], float)
    assert isinstance(rec["violations"], tuple)
    assert rec["spec_ok"] in (True, False, None)
    json.dumps(sub.to_records())

    # exports to disk round-trip
    path = os.path.join(tmp_path, "res.json")
    res.to_json(path)
    with open(path) as fh:
        assert len(json.load(fh)) == len(res)


def test_columnar_concatenates_with_optimize_records():
    """The test_design idiom: records from run() + optimize() concatenate
    into a fresh StudyResult and stay queryable."""
    res = _study(configs={"none": None}).run()
    extra = dict(res.records[0])
    extra.update({"config": "designed[hybrid]", "designed": True,
                  "mpf_frac": 0.8, "battery_capacity_j": 1e4})
    both = core.StudyResult(records=res.records + [extra])
    assert len(both) == len(res) + 1
    assert len(both.filter(designed=True)) == 1
    assert both.filter(designed=True)[0]["mpf_frac"] == 0.8


def test_columnar_rejects_both_representations():
    with pytest.raises(ValueError):
        StudyResult(records=[{}], columns={"index": np.arange(1)})


# ---------------------------------------------------------------------------
# mesh sharding plan + chunking (forced multi-device subprocess)
# ---------------------------------------------------------------------------

def test_scenario_plan_shapes():
    plan = scenario_plan()
    assert plan.n_shards >= 1 and plan.n_processes == 1
    assert plan.pad_rows(plan.n_shards + 1) == (
        (-(plan.n_shards + 1)) % plan.n_shards)
    assert plan.local_rows(8) == slice(0, 8)
    custom = ScenarioShardPlan.make(axis="scn")
    assert custom.axis == "scn" and custom.mesh.axis_names == ("scn",)


SHARD_STREAM_SCRIPT = r"""
import jax
import numpy as np
import repro.core as core
from repro.parallel.sharding import ScenarioShardPlan, scenario_plan

assert jax.device_count() == 2
plan = scenario_plan()
assert plan.n_shards == 2
# shard_batch pads to a shard multiple and commits to the mesh
import jax.numpy as jnp
tree, B = plan.shard_batch((jnp.ones((3, 8)), jnp.arange(3.0)), 3)
assert B == 4 and tree[0].shape == (4, 8)
assert tree[0].sharding.spec == jax.sharding.PartitionSpec("scenario")

tl = core.synthetic_timeline(1.0, 0.3)
cfg = core.WaveformConfig(dt=0.002, steps=3, jitter_s=0.002)
gpu = lambda m: core.GpuPowerSmoothing(mpf_frac=m, ramp_up_w_per_s=2000,
                                       ramp_down_w_per_s=2000,
                                       stop_delay_s=1.0)
spec = core.example_specs(job_mw=0.05)["moderate"]
kw = dict(workloads={"w": tl, "w2": core.synthetic_timeline(2.0, 0.25)},
          fleets=[128, 256],
          configs={"none": None, "a": (gpu(0.8), None), "b": (gpu(0.65), None)},
          specs=spec, wave_cfg=cfg, key=0)
ns = core.Study(**kw).run()                                    # unsharded
sh = core.Study(**kw, shard_devices=True).run(stream=5)        # sharded+chunked
pl = core.Study(**kw, plan=plan).run(stream=3)                 # explicit plan
assert len(sh) == len(ns) == len(pl) == 12
assert sh.records == pl.records
for a, b in zip(sh.records, ns.records):
    assert a["spec_ok"] == b["spec_ok"]
    np.testing.assert_allclose(a["energy_overhead"], b["energy_overhead"],
                               rtol=1e-5, atol=1e-8)
print("SHARD_STREAM_OK")
"""


def test_sharded_plus_chunked_matches_unsharded():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", SHARD_STREAM_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARD_STREAM_OK" in out.stdout


# ---------------------------------------------------------------------------
# serve path: streaming + metrics-only retention
# ---------------------------------------------------------------------------

def test_service_streams_and_retains_metrics_only():
    from repro.serve.power import PowerComplianceService
    svc = PowerComplianceService(wave_cfg=_cfg(steps=4),
                                 mpf_grid=(0.8,), cap_fracs=(1.0,),
                                 stream_chunk=2)
    calls = []
    tl = _tl()
    answer = svc.query(tl, N_CHIPS, "moderate",
                       on_chunk=lambda d, t, e: calls.append((d, t)))
    assert calls and calls[-1][0] == calls[-1][1] == 4
    # the retained result is columnar metrics only — no waveforms
    assert svc.last_result.waveforms is None
    ref = PowerComplianceService(wave_cfg=_cfg(steps=4), mpf_grid=(0.8,),
                                 cap_fracs=(1.0,)).query(tl, N_CHIPS,
                                                         "moderate")
    assert {p["config"]: p["energy_overhead"] for p in answer["passing"]} \
        == {p["config"]: p["energy_overhead"] for p in ref["passing"]}
    # cache hits do not re-run the study (no further on_chunk calls)
    n_calls = len(calls)
    assert svc.query(tl, N_CHIPS, "moderate",
                     on_chunk=lambda d, t, e: calls.append((d, t))) is answer
    assert len(calls) == n_calls

"""Declarative Study API: parity with the serial path, keyed randomness,
pad-and-mask fusion, result helpers, and the serve-path compliance query.

The acceptance contract (ISSUE 2): a single Study declaring >=2 workload
lengths, >=1 disabled-mitigation baseline, and noisy telemetry with
per-scenario keys runs in one ``Study.run()`` call with spec verdicts
matching the equivalent serial ``simulate()`` loop.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import repro.core as core
from repro.core import engine
from repro.core.study import MitigationConfig

DT = 0.002
N_CHIPS = 256


def _tl(period=1.0, comm=0.3, moe=False):
    return core.synthetic_timeline(period_s=period, comm_frac=comm,
                                   moe_notch=moe)


def _cfg(**kw):
    kw.setdefault("dt", DT)
    kw.setdefault("steps", 4)
    return core.WaveformConfig(**kw)


def _gpu(mpf):
    return core.GpuPowerSmoothing(mpf_frac=mpf, ramp_up_w_per_s=2000,
                                  ramp_down_w_per_s=2000, stop_delay_s=1.0)


def _noisy_firefly():
    return core.Firefly(telemetry=core.TelemetrySource(
        period_s=0.002, latency_s=0.002, noise_w=20.0))


def _swing(tl, cfg):
    dc = core.aggregate(core.chip_waveform(tl, cfg), N_CHIPS, cfg)
    return float(dc.max() - dc.min()), dc


def _acceptance_study(**kw):
    """>=2 workload lengths, a disabled baseline, noisy telemetry."""
    cfg = _cfg(jitter_s=0.002)
    tl_short, tl_long = _tl(1.0), _tl(2.0, moe=True)
    swing, dc = _swing(tl_short, cfg)
    bat = core.RackBattery(capacity_j=swing, max_discharge_w=swing,
                           max_charge_w=swing, target_tau_s=5.0)
    spec = core.example_specs(job_mw=dc.mean() / 1e6)["moderate"]
    return core.Study(
        {"short": tl_short, "long": tl_long},
        fleets=[N_CHIPS],
        configs={"none": None,
                 "mpf80+bat": (_gpu(0.8), bat),
                 "noisy_ff": (_noisy_firefly(), None)},
        specs=spec, seeds=[0, 1], wave_cfg=cfg, key=0, **kw)


# ---------------------------------------------------------------------------
# acceptance: one padded run == the serial loop
# ---------------------------------------------------------------------------

def test_study_padded_run_matches_serial_loop():
    study = _acceptance_study()
    res = study.run(padding="pad")       # ONE fused pipeline call
    assert len(res) == 12
    for sc in study.scenarios():
        ref = core.simulate(
            study.workloads[sc.workload], sc.n_chips, study.wave_cfg,
            device_mitigation=sc.config.device,
            rack_mitigation=sc.config.rack, spec=sc.spec, seed=sc.seed,
            key=study.scenario_key(sc.row))
        rec = res[sc.index]
        # spec verdicts + violation sets match for every scenario
        assert rec["spec_ok"] == ref.spec_report.ok, sc
        assert rec["violations"] == ref.spec_report.violations, sc
        if sc.config.name != "noisy_ff":
            # noise-free rows are numerically exact (noise draws are
            # length-dependent, so noisy rows are verdict-level only)
            np.testing.assert_allclose(rec["energy_overhead"],
                                       ref.energy_overhead,
                                       rtol=1e-3, atol=1e-6)
            np.testing.assert_allclose(
                rec["swing_mitigated_mw"],
                ref.swing_mitigated["swing_w"] / 1e6, rtol=1e-4, atol=1e-6)
            for k, v in ref.spec_report.metrics.items():
                np.testing.assert_allclose(rec["metrics"][k], v,
                                           rtol=5e-3, atol=2e-3, err_msg=k)


def test_study_bucket_mode_matches_serial_exactly():
    """Bucket mode runs each length unpadded, so even the noisy rows are
    bit-compatible with the keyed serial reference."""
    study = _acceptance_study(keep_waveforms=True)
    res = study.run(padding="bucket")
    for sc in study.scenarios():
        ref = core.simulate(
            study.workloads[sc.workload], sc.n_chips, study.wave_cfg,
            device_mitigation=sc.config.device,
            rack_mitigation=sc.config.rack, spec=sc.spec, seed=sc.seed,
            key=study.scenario_key(sc.row))
        np.testing.assert_allclose(res.waveforms[sc.row]["dc_mitigated"],
                                   ref.dc_mitigated, rtol=1e-4, atol=1e-2)
        assert res[sc.index]["spec_ok"] == ref.spec_report.ok


def test_study_padding_modes_agree():
    study = _acceptance_study()
    pad = study.run(padding="pad")
    bucket = study.run(padding="bucket")
    for a, b in zip(pad.records, bucket.records):
        assert a["spec_ok"] == b["spec_ok"]
        if a["config"] != "noisy_ff":
            np.testing.assert_allclose(a["energy_overhead"],
                                       b["energy_overhead"],
                                       rtol=1e-4, atol=1e-7)


# ---------------------------------------------------------------------------
# keyed randomness
# ---------------------------------------------------------------------------

def test_keyed_noise_draws_are_independent_per_scenario():
    cfg = _cfg(jitter_s=0.0)
    study = core.Study({"w": _tl()}, fleets=[64],
                       configs={"ff": (_noisy_firefly(), None)},
                       seeds=[0, 1], wave_cfg=cfg, key=0,
                       keep_waveforms=True)
    res = study.run()
    # jitter off + same config: the ONLY difference between the rows is
    # the per-scenario PRNG key, so the waveforms must differ
    assert not np.array_equal(res.waveforms[0]["dc_mitigated"],
                              res.waveforms[1]["dc_mitigated"])

    legacy = core.Study({"w": _tl()}, fleets=[64],
                        configs={"ff": (_noisy_firefly(), None)},
                        seeds=[0, 1], wave_cfg=cfg, key=None,
                        keep_waveforms=True)
    lres = legacy.run()
    # key=None reverts to the legacy shared draw: rows are identical
    np.testing.assert_array_equal(lres.waveforms[0]["dc_mitigated"],
                                  lres.waveforms[1]["dc_mitigated"])


def test_same_root_key_is_bit_reproducible():
    a = _acceptance_study(keep_waveforms=True).run()
    b = _acceptance_study(keep_waveforms=True).run()
    assert a.records == b.records
    for wa, wb in zip(a.waveforms, b.waveforms):
        np.testing.assert_array_equal(wa["dc_mitigated"], wb["dc_mitigated"])


# ---------------------------------------------------------------------------
# declaration + result helpers
# ---------------------------------------------------------------------------

def test_study_axes_and_spec_dedup():
    cfg = _cfg()
    specs = core.example_specs(job_mw=0.05)
    study = core.Study({"w": _tl()}, fleets=[128, 256],
                       configs={"none": None, "mpf80": (_gpu(0.8), None)},
                       specs={"moderate": specs["moderate"],
                              "tight": specs["tight"]},
                       wave_cfg=cfg, key=0)
    assert study.n_rows == 4 and len(study) == 8
    res = study.run()
    # the spec axis shares physics: same row metrics under both specs
    by_row = {}
    for r in res:
        by_row.setdefault(r["row"], []).append(r)
    for rows in by_row.values():
        assert len(rows) == 2
        assert rows[0]["energy_overhead"] == rows[1]["energy_overhead"]
        assert {rows[0]["spec"], rows[1]["spec"]} == {"moderate", "tight"}


def test_study_composes_with_pallas_backstop():
    """The kernel-enabled backstop (use_pallas meta field) rides through
    the declarative layer — mixed-length fusion, baseline masking and the
    vmapped pipeline — with serial verdict parity."""
    cfg = _cfg(jitter_s=0.002)
    tl_short, tl_long = _tl(1.0), _tl(2.0, moe=True)
    swing, dc = _swing(tl_short, cfg)
    bs = core.TelemetryBackstop(critical_hz=(0.5, 1.0), window_s=2.0,
                                sustain_s=0.5, amp_threshold_w=0.05 * swing,
                                use_pallas=True)
    spec = core.example_specs(job_mw=dc.mean() / 1e6)["moderate"]
    study = core.Study({"short": tl_short, "long": tl_long},
                       fleets=[N_CHIPS],
                       configs={"none": None, "backstop": (None, bs)},
                       specs=spec, wave_cfg=cfg, key=None)
    res = study.run(padding="pad")
    assert len(res) == 4
    for sc in study.scenarios():
        ref = core.simulate(study.workloads[sc.workload], sc.n_chips,
                            study.wave_cfg, device_mitigation=sc.config.device,
                            rack_mitigation=sc.config.rack, spec=sc.spec,
                            seed=sc.seed)
        assert res[sc.index]["spec_ok"] == ref.spec_report.ok, sc
        assert res[sc.index]["violations"] == ref.spec_report.violations, sc


def test_study_rejects_bad_declarations():
    with pytest.raises(ValueError):
        core.Study({"w": _tl()}, padding="fuse")
    with pytest.raises(TypeError):
        core.Study({"w": _tl()}, configs={"bare": _gpu(0.8)})
    with pytest.raises(ValueError):
        core.Study({"w": _tl()},
                   configs=[MitigationConfig("dup"), MitigationConfig("dup")])


def test_result_helpers_filter_pivot_export(tmp_path):
    study = _acceptance_study()
    res = study.run()
    sub = res.filter(workload="short", config=["none", "mpf80+bat"])
    assert len(sub) == 4 and set(sub.unique("config")) == {"none",
                                                          "mpf80+bat"}
    assert len(res.passing()) + len(res.failing()) == len(res)
    piv = res.filter(seed=0).pivot("workload", "config", "spec_ok")
    assert set(piv) == {"short", "long"}
    assert set(piv["short"]) == {"none", "mpf80+bat", "noisy_ff"}
    best = res.best()
    if best is not None:
        assert best["spec_ok"]
    # exports round-trip and are JSON/CSV-safe
    j = json.loads(res.to_json(os.path.join(tmp_path, "r.json")))
    assert len(j) == len(res) and isinstance(j[0]["violations"], list)
    csv_text = res.to_csv(os.path.join(tmp_path, "r.csv"))
    assert csv_text.count("\n") == len(res) + 1
    assert "| workload |" in res.table().splitlines()[0]


def test_passing_configs_orders_by_worst_overhead():
    study = _acceptance_study()
    res = study.run()
    names = res.passing_configs()
    assert "none" not in names           # raw waveform violates the spec
    worst = [max(r["energy_overhead"] for r in res.filter(config=c))
             for c in names]
    assert worst == sorted(worst)


# ---------------------------------------------------------------------------
# engine-level pad-and-mask (the lever Study drives)
# ---------------------------------------------------------------------------

def test_simulate_batch_pad_to_is_exact_in_valid_region():
    cfg = _cfg(jitter_s=0.002)
    tls = [_tl(1.0), _tl(2.0, moe=True)]
    swing, _ = _swing(tls[0], cfg)
    bat = core.RackBattery(capacity_j=swing, max_discharge_w=swing,
                           max_charge_w=swing, target_tau_s=5.0)
    lens = [len(core.chip_waveform(t, cfg)) for t in tls]
    res = engine.simulate_batch(tls, N_CHIPS, cfg,
                                device_mitigation=[_gpu(0.8), None],
                                rack_mitigation=bat, seeds=3,
                                pad_to=max(lens), spectra=False)
    assert list(res.n_valid) == lens
    for i, tl in enumerate(tls):
        ref = core.simulate(tl, N_CHIPS, cfg,
                            device_mitigation=_gpu(0.8) if i == 0 else None,
                            rack_mitigation=bat, seed=3)
        n = res.length(i)
        np.testing.assert_allclose(res.dc_mitigated[i, :n], ref.dc_mitigated,
                                   rtol=1e-5, atol=1e-2)
        np.testing.assert_allclose(res.energy_overhead[i],
                                   ref.energy_overhead, rtol=1e-3, atol=1e-6)
        for k, v in ref.swing_mitigated.items():
            np.testing.assert_allclose(res.swing_mitigated[k][i], v,
                                       rtol=1e-4, atol=1e-3, err_msg=k)


def test_simulate_batch_pad_to_rejects_spec_and_spectra():
    with pytest.raises(ValueError):
        engine.simulate_batch(_tl(), N_CHIPS, _cfg(), pad_to=99999,
                              spec=core.example_specs(0.1)["moderate"],
                              spectra=False)


# ---------------------------------------------------------------------------
# scenario-axis sharding (forced multi-device subprocess)
# ---------------------------------------------------------------------------

SHARD_SCRIPT = r"""
import numpy as np
import repro.core as core
tl = core.synthetic_timeline(1.0, 0.3)
cfg = core.WaveformConfig(dt=0.002, steps=3, jitter_s=0.002)
gpu = lambda m: core.GpuPowerSmoothing(mpf_frac=m, ramp_up_w_per_s=2000,
                                       ramp_down_w_per_s=2000,
                                       stop_delay_s=1.0)
spec = core.example_specs(job_mw=0.05)["moderate"]
kw = dict(workloads={"w": tl}, fleets=[128, 256],
          configs={"none": None, "a": (gpu(0.8), None), "b": (gpu(0.65), None)},
          specs=spec, wave_cfg=cfg, key=0)
sh = core.Study(**kw, shard_devices=True).run()   # 6 rows over 2 devices
ns = core.Study(**kw).run()
assert len(sh) == len(ns) == 6
for a, b in zip(sh.records, ns.records):
    assert a["spec_ok"] == b["spec_ok"]
    np.testing.assert_allclose(a["energy_overhead"], b["energy_overhead"],
                               rtol=1e-5, atol=1e-8)
print("SHARD_OK")
"""


def test_shard_devices_matches_unsharded():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", SHARD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARD_OK" in out.stdout


# ---------------------------------------------------------------------------
# serve path: the compliance query service
# ---------------------------------------------------------------------------

def _service():
    from repro.serve.power import PowerComplianceService
    return PowerComplianceService(
        wave_cfg=_cfg(steps=4, jitter_s=0.002),
        mpf_grid=(0.8,), cap_fracs=(1.0,))


def test_compliance_query_answer_matches_serial_verdicts():
    svc = _service()
    tl = _tl()
    answer = svc.query(tl, N_CHIPS, "moderate")
    assert answer["n_configs"] == 4      # none, mpf80, bat1x, mpf80+bat1x
    assert set(p["config"] for p in answer["passing"]).isdisjoint({"none"})
    # every claimed-passing config really passes the spec serially
    result = svc.last_result
    for p in answer["passing"]:
        for rec in result.filter(config=p["config"]):
            assert rec["spec_ok"], p
    # ... and the answer is cached
    assert svc.query(tl, N_CHIPS, "moderate") is answer


def test_compliance_handle_is_json_safe():
    svc = _service()
    ans = svc.handle({"workload": {"period_s": 1.0, "comm_frac": 0.3},
                      "n_chips": N_CHIPS, "spec": "lenient"})
    assert "error" not in ans
    json.dumps(ans)                      # fully serializable
    assert ans["spec"] == "lenient" and isinstance(ans["passing"], list)
    err = svc.handle({"workload": 42, "n_chips": N_CHIPS})
    assert "error" in err
    err = svc.handle({"workload": {"cell": "/no/such/cell.json"},
                      "n_chips": N_CHIPS})
    assert "error" in err                # bad path stays inside the boundary

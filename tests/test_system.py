"""End-to-end system behaviour: the power-aware training pipeline.

Train a small model -> derive its phase timeline (as the dry-run would) ->
simulate the datacenter waveform -> show the raw job violates a moderate
utility spec -> apply the paper's combined mitigation -> spec passes -> the
backstop stays quiet -> ballast-enabled training is numerically identical.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as core
from repro.configs import TrainConfig, get_config, reduced
from repro.data import SyntheticLM
from repro.train import init_train_state, make_train_step


def test_power_aware_training_pipeline():
    # --- 1. train a real (tiny) model
    cfg = reduced(get_config("granite-3-8b"))
    tcfg = TrainConfig(learning_rate=5e-3, warmup_steps=2, total_steps=20)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    data = SyntheticLM(cfg, batch=4, seq=32, seed=0)
    for i in range(5):
        state, metrics = step(state, {k: jnp.asarray(v) for k, v in data(i).items()})
    assert np.isfinite(float(metrics["loss"]))

    # --- 2. a dry-run-shaped artifact for this job (as launch/dryrun emits)
    cell = {"n_chips": 512,
            "exact": {"flops": 2.5e16, "bytes": 3.0e15},
            "collectives": {"all-reduce": 2.2e11, "all-gather": 4e10},
            "memory": {"state_bytes_per_device": 4e9}}
    tl = core.from_dryrun_cell(cell)
    assert tl.period_s > 0.1

    # --- 3. raw job violates the moderate spec
    wave_cfg = core.WaveformConfig(dt=0.002, steps=25, jitter_s=0.002)
    raw = core.simulate(tl, cell["n_chips"], wave_cfg)
    spec = core.example_specs(job_mw=raw.dc_raw.mean() / 1e6)["moderate"]
    raw_report = spec.validate(raw.dc_raw, wave_cfg.dt)
    assert not raw_report.ok

    # --- 4. the paper's combined mitigation brings it into spec
    sol = core.design_mitigation(spec, raw.dc_raw, wave_cfg.dt, cell["n_chips"])
    assert sol is not None and sol["report"].ok
    assert sol["energy_overhead"] < 0.6

    # --- 5. backstop stays quiet on the mitigated waveform
    swing = raw.dc_raw.max() - raw.dc_raw.min()
    gpu = core.GpuPowerSmoothing(mpf_frac=max(sol["mpf_frac"], 0.5),
                                 ramp_up_w_per_s=2000, ramp_down_w_per_s=2000)
    bat = core.RackBattery(capacity_j=max(sol["battery_capacity_j"], swing),
                           max_discharge_w=swing, max_charge_w=swing)
    mit = core.CombinedMitigation(gpu, bat, cell["n_chips"])
    res = core.simulate(tl, cell["n_chips"], wave_cfg, device_mitigation=gpu,
                        rack_mitigation=bat)
    bs = core.TelemetryBackstop(critical_hz=(0.5, 1.0, 2.0),
                                amp_threshold_w=0.25 * swing, window_s=6.0)
    _, aux = bs.apply(res.dc_mitigated, wave_cfg.dt)
    _, aux_raw = bs.apply(res.dc_raw, wave_cfg.dt)
    assert aux["max_level"] <= aux_raw["max_level"]

    # --- 6. ballast-enabled training: same numbers, extra MXU work
    tb = dataclasses.replace(tcfg, ballast=True, ballast_gflops=0.005)
    sb = init_train_state(jax.random.PRNGKey(0), cfg, tb)
    s0 = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    batch = {k: jnp.asarray(v) for k, v in data(0).items()}
    s0b, m0 = jax.jit(make_train_step(cfg, tcfg))(s0, batch)
    sbb, mb = jax.jit(make_train_step(cfg, tb))(sb, batch)
    np.testing.assert_allclose(float(m0["loss"]), float(mb["loss"]), rtol=1e-6)


def test_staggered_restart_meets_ramp_spec():
    """Power-aware restart: a mass restore slams the fleet; the stagger
    schedule keeps the aggregate ramp inside the utility limit."""
    hw = core.DEFAULT_HW
    n_racks = 16
    rack_w = hw.topo.chips_per_rack * hw.chip.tdp_w
    job_w = n_racks * rack_w
    spec = core.example_specs(job_mw=job_w / 1e6)["tight"]
    sched = core.plan_stagger(n_racks, rack_w, spec.time.ramp_up_w_per_s,
                              rack_ramp_s=2.0)
    w = core.ramp_waveform(sched, n_racks, rack_w, dt=0.01)
    assert core.max_ramp(w, 0.01) <= spec.time.ramp_up_w_per_s * 1.05
    assert sched.total_s < 120.0  # restart completes in bounded time

"""Training-loop integration: convergence, accumulation, ballast, schedules."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig, get_config, reduced
from repro.core.ballast_inject import attach_ballast, ballast_gflops_for_cell
from repro.data import SyntheticLM
from repro.train import init_train_state, make_train_step
from repro.train.optimizer import lr_schedule

from conftest import tiny_batch


def _train(cfg, tcfg, steps, seed=0, batch=8, seq=32):
    state = init_train_state(jax.random.PRNGKey(seed), cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    data = SyntheticLM(cfg, batch=batch, seq=seq, seed=0)
    losses = []
    for i in range(steps):
        state, m = step(state, {k: jnp.asarray(v) for k, v in data(i).items()})
        losses.append(float(m["loss"]))
    return state, losses


def test_overfit_tiny_model():
    cfg = reduced(get_config("granite-3-8b"))
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=5, total_steps=60)
    _, losses = _train(cfg, tcfg, 60)
    assert losses[-1] < losses[0] - 1.5, (losses[0], losses[-1])


def test_grad_accumulation_equivalent():
    """Microbatched accumulation == single batch (up to f32 reassociation)."""
    cfg = reduced(get_config("granite-3-8b"))
    t1 = TrainConfig(learning_rate=1e-2, warmup_steps=5, total_steps=10)
    t4 = dataclasses.replace(t1, microbatches=4)
    s1 = init_train_state(jax.random.PRNGKey(0), cfg, t1)
    s4 = init_train_state(jax.random.PRNGKey(0), cfg, t4)
    batch = tiny_batch(cfg, B=8, S=16)
    s1b, _ = jax.jit(make_train_step(cfg, t1))(s1, batch)
    s4b, _ = jax.jit(make_train_step(cfg, t4))(s4, batch)
    for a, b in zip(jax.tree.leaves(s1b.params), jax.tree.leaves(s4b.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=2e-6)


def test_ballast_preserves_loss_but_adds_flops():
    loss = jnp.asarray(3.14159, jnp.float32)
    out = attach_ballast(loss, gflops=0.01)
    assert float(out) == float(loss)  # 1e-30 tie-in below fp32 resolution
    # the ballast dots survive XLA optimization (anti-DCE check)
    hlo = jax.jit(lambda l: attach_ballast(l, 0.01)).lower(loss).compile().as_text()
    assert "dot" in hlo and "while" in hlo


def test_ballast_sizing_from_cell():
    cell = {"collectives": {"all-reduce": 4e11}}
    g = ballast_gflops_for_cell(cell)
    # 4e11 B / 200 GB/s = 2 s exposed; 0.9*197e12*2 = ~354 TFLOP
    assert 3e5 < g < 4e5


def test_ballast_in_train_step():
    cfg = reduced(get_config("granite-3-8b"))
    t0 = TrainConfig(learning_rate=1e-3, warmup_steps=5, total_steps=10)
    tb = dataclasses.replace(t0, ballast=True, ballast_gflops=0.01)
    batch = tiny_batch(cfg)
    s0 = init_train_state(jax.random.PRNGKey(0), cfg, t0)
    sb = init_train_state(jax.random.PRNGKey(0), cfg, tb)
    s0b, m0 = jax.jit(make_train_step(cfg, t0))(s0, batch)
    sbb, mb = jax.jit(make_train_step(cfg, tb))(sb, batch)
    # identical training result — ballast is numerically inert
    np.testing.assert_allclose(float(m0["loss"]), float(mb["loss"]), rtol=1e-6)
    for a, b in zip(jax.tree.leaves(s0b.params), jax.tree.leaves(sbb.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7)


def test_lr_schedule_shape():
    t = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(jnp.asarray(s), t)) for s in range(100)]
    assert lrs[0] > 0                       # no dead first step
    assert np.argmax(lrs) <= 10             # peak at end of warmup
    assert lrs[-1] < 0.2 * max(lrs)         # cosine decays
    assert all(l > 0 for l in lrs)


def test_weight_decay_mask():
    cfg = reduced(get_config("qwen1.5-110b"))  # has biases
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=1, total_steps=5,
                       weight_decay=10.0)  # exaggerated decay
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    batch = tiny_batch(cfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    s2, _ = step(state, batch)
    # norms exempt from decay: ones stay ~ones + gradient-sized update
    n0 = np.asarray(jax.tree.leaves(state.params)[-1])
    # check a norm leaf specifically
    before = np.asarray(state.params["final_norm"])
    after = np.asarray(s2.params["final_norm"])
    assert np.abs(after - before).max() < 0.1  # decay(10.0)*lr would dwarf this

"""Warm-start design amortization: spectral fingerprint, predictor
training/checkpointing, the hard-revalidated ``design(method="warmstart")``
path, and the spec family/limits split that keys compiled executables."""
import numpy as np
import pytest

import repro.core as core
from repro.core import engine
from repro.core.spectrum import (GRID_CRITICAL_HZ, goertzel_bin_amplitudes,
                                 goertzel_bin_amplitudes_jax)


def _problem(n_chips=512, steps=3, dt=0.01, period_s=1.0, comm_frac=0.3,
             spec_name="moderate"):
    tl = core.synthetic_timeline(period_s=period_s, comm_frac=comm_frac)
    cfg = core.WaveformConfig(dt=dt, steps=steps, jitter_s=dt)
    w = core.aggregate(core.chip_waveform(tl, cfg), n_chips, cfg)
    spec = core.example_specs(job_mw=float(w.mean()) / 1e6)[spec_name]
    return w, cfg, spec


# -- spectral fingerprint ---------------------------------------------------

def test_goertzel_reports_pure_tone_amplitude():
    dt, n, amp, f0 = 0.002, 4000, 3e5, 2.0
    t = np.arange(n) * dt
    x = 5e8 + amp * np.sin(2 * np.pi * f0 * t)
    amps = goertzel_bin_amplitudes(x, dt, GRID_CRITICAL_HZ)
    i0 = GRID_CRITICAL_HZ.index(f0)
    assert amps[i0] == pytest.approx(amp, rel=0.02)
    others = np.delete(amps, i0)
    assert others.max() < 0.1 * amp


def test_goertzel_jax_mirror_matches_numpy():
    rng = np.random.default_rng(0)
    x = 1e8 + 1e6 * rng.normal(size=3000)
    a_np = goertzel_bin_amplitudes(x, 0.004, GRID_CRITICAL_HZ)
    a_jx = np.asarray(goertzel_bin_amplitudes_jax(x, 0.004, GRID_CRITICAL_HZ))
    np.testing.assert_allclose(a_jx, a_np, rtol=2e-3, atol=1.0)


def test_features_finite_and_swing_recovered():
    from repro.serve.warmstart import (FEATURE_NAMES, extract_features,
                                      swings_from_features)
    w, cfg, spec = _problem()
    f = extract_features(spec, w, cfg.dt, 512)
    assert f.shape == (len(FEATURE_NAMES),) and np.isfinite(f).all()
    swing = float(w.max() - w.min())
    got = float(swings_from_features(f[None])[0])
    assert got == pytest.approx(swing, rel=1e-3)


# -- training + checkpoint --------------------------------------------------

def _toy_dataset(w, cfg, spec, n_chips=512):
    from repro.serve.warmstart import extract_features
    f = extract_features(spec, w, cfg.dt, n_chips)
    rng = np.random.default_rng(0)
    X = np.tile(f, (48, 1)) + rng.normal(0, 0.01, (48, len(f))).astype(
        np.float32)
    X[0] = f
    swing = float(w.max() - w.min())
    Y = np.tile(np.asarray([0.7, swing * 1.2, 15.0], np.float32), (48, 1))
    return f, X, Y


def test_train_loss_decreases_and_predicts_training_point():
    from repro.serve.warmstart import train_warmstart
    w, cfg, spec = _problem()
    f, X, Y = _toy_dataset(w, cfg, spec)
    pred, hist = train_warmstart(X, Y, epochs=200, batch_size=24, seed=0)
    assert hist["loss"][-1] < 0.01 * hist["loss"][0]
    mpf, cap, tau = pred(spec, w, cfg.dt, 512, features=f)[0]
    assert mpf == pytest.approx(0.7, abs=0.08)
    assert cap == pytest.approx(float(Y[0, 1]), rel=0.15)
    assert tau == pytest.approx(15.0, abs=3.0)


def test_checkpoint_roundtrip_bit_exact(tmp_path):
    from repro.serve.warmstart import WarmStartPredictor, train_warmstart
    w, cfg, spec = _problem()
    f, X, Y = _toy_dataset(w, cfg, spec)
    pred, _ = train_warmstart(X, Y, epochs=40, batch_size=24, seed=0)
    pred.save(str(tmp_path))
    pred2 = WarmStartPredictor.load(str(tmp_path))
    np.testing.assert_array_equal(pred.predict_normalized(f),
                                  pred2.predict_normalized(f))
    assert pred2.meta["n_features"] == pred.meta["n_features"]


# -- the design path --------------------------------------------------------

def test_design_warmstart_fast_path_hard_passes():
    w, cfg, spec = _problem()
    swing = float(w.max() - w.min())
    # a stub predictor near the known-feasible battery sizing: the fast
    # ladder path must return a hard tau=0 validated config
    stub = lambda spec, w, dt, n, features=None: [(0.0, swing * 1.2, 30.0)]
    sol = engine.design(spec, w, cfg.dt, 512, method="warmstart",
                        warmstart=stub)
    assert sol is not None and sol["report"].ok
    assert sol["aux"]["warmstart_path"] == "fast"
    assert sol["method"] == "warmstart"
    assert sol["target_tau_s"] == 30.0


def test_design_warmstart_verdict_matches_hybrid_on_bad_seeds():
    # a predictor that misses badly: the escalation tiers must still
    # agree with the solver the warm start amortizes
    w, cfg, spec = _problem()
    bad = lambda spec, w, dt, n, features=None: [(0.05, 1.0, 5.0)]
    sol_w = engine.design(spec, w, cfg.dt, 512, method="warmstart",
                         warmstart=bad)
    sol_h = engine.design(spec, w, cfg.dt, 512, method="hybrid")
    assert (sol_w is None) == (sol_h is None)
    assert sol_w["report"].ok and sol_h["report"].ok
    assert sol_w["aux"]["warmstart_path"] in ("polish", "hybrid_fallback")


def test_design_warmstart_requires_predictor():
    w, cfg, spec = _problem()
    with pytest.raises(ValueError, match="warmstart"):
        engine.design(spec, w, cfg.dt, 512, method="warmstart")


# -- spec family/limits split (the cross-query compiled-reuse keying) -------

def test_family_limits_validation_parity():
    w, cfg, _ = _problem()
    for name in ("lenient", "moderate", "tight"):
        spec = core.example_specs(job_mw=float(w.mean()) / 1e6)[name]
        report = spec.validate(np.asarray(w), cfg.dt)
        ok_fam = bool(np.asarray(
            spec.family().validate_jax(w, cfg.dt, spec.limits())[0]))
        assert ok_fam == report.ok


def test_no_retrace_across_spec_thresholds():
    w, cfg, _ = _problem()
    ws = np.stack([w, w * 1.01])
    spec_a = core.example_specs(job_mw=10.0)["moderate"]
    spec_b = core.example_specs(job_mw=25.0)["moderate"]
    engine.validate_many(ws, spec_a, cfg.dt)
    size_after_first = engine._validate_vmapped._cache_size()
    ok_a, _ = engine.validate_many(ws, spec_a, cfg.dt)
    ok_b, _ = engine.validate_many(ws, spec_b, cfg.dt)
    assert engine._validate_vmapped._cache_size() == size_after_first, \
        "new spec thresholds retraced the validation executable"
    assert ok_a.shape == ok_b.shape == (2,)
